"""Pallas TPU kernel: 256-bin histogram (codec LUT calibration).

Formulated as a one-hot reduction: for a (TILE_ROWS, LANES) tile of
symbols, counts[s] += sum(sym == s). The comparison+sum vectorizes on
the VPU; per-grid-step accumulation uses the standard Pallas pattern of
mapping every grid step to the same output block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = (8, 128)


def _hist_kernel(sym_ref, out_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    sym = sym_ref[...].astype(jnp.int32)             # (TR, TL)
    bins = jax.lax.broadcasted_iota(jnp.int32, (256,), 0)
    onehot = (sym.reshape(-1)[:, None] == bins[None, :]).astype(jnp.int32)
    out_ref[...] += jnp.sum(onehot, axis=0)


@functools.partial(jax.jit, static_argnames=("tile_rows", "interpret"))
def histogram256_pallas(symbols: jnp.ndarray, *, tile_rows: int = 8,
                        interpret: bool = True) -> jnp.ndarray:
    """uint8 [rows, 128*m] -> int32 [256] counts (ops.py pads/reshapes)."""
    rows, cols = symbols.shape
    assert rows % tile_rows == 0, (rows, tile_rows)
    grid = (rows // tile_rows,)

    return pl.pallas_call(
        _hist_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile_rows, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((256,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((256,), jnp.int32),
        interpret=interpret,
    )(symbols)
