"""Config registry: one module per assigned architecture (+ the paper's
own Gemma-2B SFT setting). ``get_config(arch)`` is the ``--arch`` entry
point; ``reduced(cfg)`` builds the small same-family smoke variant."""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import (  # noqa: F401
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    ModelConfig,
    MoEConfig,
    PREFILL_32K,
    ShapeConfig,
    TRAIN_4K,
)

from repro.configs import (
    chatglm3_6b,
    deepseek_coder_33b,
    deepseek_moe_16b,
    gemma_2b_sft,
    jamba_1_5_large_398b,
    mixtral_8x22b,
    musicgen_medium,
    nemotron_4_340b,
    phi3_mini_3_8b,
    phi3_vision_4_2b,
    xlstm_125m,
)

_MODULES = (
    deepseek_coder_33b, chatglm3_6b, nemotron_4_340b, phi3_mini_3_8b,
    phi3_vision_4_2b, musicgen_medium, jamba_1_5_large_398b,
    deepseek_moe_16b, mixtral_8x22b, xlstm_125m, gemma_2b_sft,
)

REGISTRY: Dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG
                                    for m in _MODULES}

#: The ten assigned architectures (gemma-2b-sft is the paper's own,
#: used by examples/benchmarks, not part of the 40-cell sweep).
ASSIGNED = tuple(n for n in REGISTRY if n != "gemma-2b-sft")


def get_config(arch: str) -> ModelConfig:
    if arch not in REGISTRY:
        raise KeyError(
            f"unknown arch {arch!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[arch]


def shapes_for(cfg: ModelConfig) -> tuple:
    """The assigned shape cells this arch runs (long_500k only for
    sub-quadratic archs, per the assignment brief)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.supports_long_context:
        out.append(LONG_500K)
    return tuple(out)


def skipped_shapes_for(cfg: ModelConfig) -> tuple:
    return () if cfg.supports_long_context else (LONG_500K,)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Small same-family config for CPU smoke tests: same block kinds,
    activation, routing structure; tiny widths/depth/vocab."""
    period = cfg.layer_period
    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=max(period, 2 if period == 1 else period),
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2))
        if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=256,
        max_seq_len=512,
        frontend_prefix_len=8 if cfg.frontend else 0,
        attn_q_block=16,
        attn_kv_block=32,
        remat="none",
    )
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(
            num_experts=4, top_k=2, d_expert=32,
            num_shared_experts=min(cfg.moe.num_shared_experts, 1))
    kw.update(overrides)
    return dataclasses.replace(cfg, **kw)
