"""The paper's own experimental setting (§3): Gemma-2B during SFT —
18 layers, sharded over 64 TPUs, FFN1/FFN2 tensors analyzed at e4m3.

arXiv:2403.08295 (Gemma 2B: 18L, d_model 2048, 8H MQA kv=1, d_ff 16384
GeGLU, vocab 256128).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b-sft",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256128,
    activation="swiglu",   # GeGLU-family gated MLP
    rope_theta=10000.0,
)
