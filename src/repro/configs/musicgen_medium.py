"""musicgen-medium [audio] — decoder-only over EnCodec tokens.

arXiv:2306.05284. The EnCodec frontend is a stub: conditioning is
modeled as 64 precomputed frame embeddings prepended to the audio-token
sequence (the real model uses text-conditioning cross-attention; see
DESIGN.md §6).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    activation="gelu",
    rope_theta=10000.0,
    frontend="audio_stub",
    frontend_prefix_len=64,
)
