"""jamba-1.5-large-398b [hybrid] — Mamba + attention 1:7 interleave,
MoE 16 experts top-2. arXiv:2403.19887.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    activation="swiglu",
    rope_theta=10000.0,
    attn_every=8,          # 1 attention layer per 8 (1:7 with mamba)
    ssm_type="mamba",
    ssm_state_dim=16,
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=24576),
    moe_every=2,           # MoE on every other layer (dense between)
)
