"""xlstm-125m [ssm] — alternating sLSTM + mLSTM blocks, no FFN (d_ff=0).

arXiv:2405.04517 (config tier: unverified).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    ssm_type="xlstm",
)
