"""Model / runtime configuration dataclasses."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # hidden width of each routed expert
    num_shared_experts: int = 0   # deepseek-moe fine-grained shared experts
    capacity_factor: float = 1.25
    router_noise: float = 0.0
    impl: str = "gspmd"           # "gspmd" | "grouped_local" | "shardmap_a2a"
    dispatch_groups: int = 32     # grouped_local: dispatch groups
    #   (= dp shard count so token->expert-buffer scatters stay
    #   shard-local instead of lowering to giant all-reduces)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    activation: str = "swiglu"    # swiglu | gelu | squared_relu
    rope_fraction: float = 1.0    # chatglm3 applies rope to half the dims
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None   # mixtral SWA
    moe: Optional[MoEConfig] = None
    moe_every: int = 1            # jamba: MoE on every 2nd layer
    attn_every: Optional[int] = None       # jamba: 1 attention per 8 layers
    ssm_type: Optional[str] = None         # mamba | xlstm
    ssm_state_dim: int = 16
    conv_kernel: int = 4
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    frontend: Optional[str] = None         # vision_stub | audio_stub
    frontend_prefix_len: int = 0           # patches/frames prepended
    max_seq_len: int = 524288
    # runtime
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "full"           # none | full | dots
    use_scan: bool = True
    attn_q_block: int = 512
    attn_kv_block: int = 1024
    attn_impl: str = "blocked"    # blocked (flash-style) | dense
    attn_score_dtype: str = "float32"   # bfloat16 halves score traffic
    pad_heads_multiple: Optional[int] = None  # pad Q heads so they
    #   shard over the model axis (frozen zero pad slices — function
    #   is exactly the unpadded arch; see models/attention.py)
    causal_skip: bool = False     # skip fully-masked KV blocks (perf opt)
    serve_params_tp_only: bool = False  # serving: no FSDP weight gathers

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def layer_period(self) -> int:
        """Heterogeneous stacks scan over groups of this many layers."""
        if self.family == "hybrid" and self.attn_every:
            return self.attn_every
        if self.ssm_type == "xlstm":
            return 2   # alternating sLSTM / mLSTM
        return 1

    def ffn_kind(self, idx_in_group: int) -> str:
        """FFN flavor for a layer: "moe" | "dense" | "none"."""
        kinds = self.layer_kinds()
        if self.d_ff == 0 or kinds[idx_in_group] not in ("attention",
                                                         "mamba"):
            return "none"
        if self.moe is not None and (
                idx_in_group % self.moe_every == self.moe_every - 1):
            return "moe"
        return "dense"

    def layer_kinds(self) -> Tuple[str, ...]:
        """Block kind for each layer within one period group."""
        if self.family == "hybrid" and self.attn_every:
            # jamba: 1 attention layer per `attn_every`, rest mamba.
            return tuple(
                "attention" if i == 0 else "mamba"
                for i in range(self.attn_every))
        if self.ssm_type == "xlstm":
            return ("slstm", "mlstm")
        if self.ssm_type == "mamba":
            return ("mamba",)
        return ("attention",)

    @property
    def is_attention_free(self) -> bool:
        return all(k not in ("attention",) for k in self.layer_kinds())

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic archs (ssm/hybrid) run the long_500k shape."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline accounting)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        h, kv, hd = self.num_heads, self.num_kv_heads, self.resolved_head_dim
        kinds_per_group = self.layer_kinds()
        n_groups = self.num_layers // len(kinds_per_group)
        total = v * d                      # embedding
        if not self.tie_embeddings:
            total += v * d                 # lm head
        per_group = 0
        for li, kind in enumerate(kinds_per_group):
            per_group += 2 * d             # two rmsnorm scales
            if kind == "attention":
                per_group += d * h * hd + 2 * d * kv * hd + h * hd * d
                per_group += self._ffn_params(li)
            elif kind == "mamba":
                di = 2 * d
                dt_rank = max(1, d // 16)
                per_group += (d * 2 * di + di * self.conv_kernel
                              + di * (dt_rank + 2 * self.ssm_state_dim)
                              + dt_rank * di + di * self.ssm_state_dim
                              + di + di * d)
                per_group += self._ffn_params(li)
            elif kind in ("slstm", "mlstm"):
                # qkv + gates + out
                per_group += 3 * d * h * hd + 4 * d * h + h * hd * d
            else:
                raise ValueError(kind)
        total += n_groups * per_group
        total += d                         # final norm
        return total

    def _ffn_params(self, idx_in_group: int = 0) -> int:
        d, ff = self.d_model, self.d_ff
        if ff == 0:
            return 0
        if self.ffn_kind(idx_in_group) == "moe":
            m = self.moe
            e_params = (m.num_experts *
                        self._mlp_params(d, m.d_expert))
            shared = (self._mlp_params(d, m.num_shared_experts * m.d_expert)
                      if m.num_shared_experts else 0)
            router = d * m.num_experts
            return e_params + shared + router
        return self._mlp_params(d, ff)

    def _mlp_params(self, d: int, ff: int) -> int:
        if ff == 0:
            return 0
        gated = self.activation in ("swiglu",)
        return (3 if gated else 2) * d * ff

    def active_param_count(self) -> int:
        """Active params per token (MoE top-k) for 6·N_active·D."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        # Only top_k of the routed experts are active per token, on the
        # layers that carry the MoE.
        kinds = self.layer_kinds()
        n_moe_layers = (self.num_layers // len(kinds)) * sum(
            1 for li in range(len(kinds)) if self.ffn_kind(li) == "moe")
        inactive = ((m.num_experts - m.top_k) *
                    self._mlp_params(self.d_model, m.d_expert))
        return self.param_count() - n_moe_layers * inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
