"""deepseek-moe-16b [moe] — fine-grained: 2 shared + 64 routed top-6
experts of width 1408. arXiv:2401.06066.

Simplification vs the HF checkpoint: the real model's first layer is a
dense FFN; we use MoE on every layer (noted in DESIGN.md §6).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    activation="swiglu",
    rope_theta=10000.0,
    moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408,
                  num_shared_experts=2),
)
