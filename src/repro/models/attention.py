"""Attention layers: GQA with RoPE, flash-style blocked softmax for long
sequences, sliding-window masking (mixtral), and KV-cache decode.

The blocked implementation is the TPU-appropriate formulation: an online
softmax over KV blocks inside a lax.scan keeps activation memory
O(S · block) instead of O(S²) (critical for the prefill_32k cells).
With ``causal_skip`` (perf opt), fully-masked KV blocks are skipped via
a q-block/kv-block scan bound, halving causal attention FLOPs.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.parallel.sharding import logical_constraint

NEG_INF = -2.0 ** 30


def padded_heads(cfg: ModelConfig) -> int:
    h, m = cfg.num_heads, cfg.pad_heads_multiple
    if not m or h % m == 0:
        return h
    return -(-h // m) * m


def init_attention(key, cfg: ModelConfig, dtype):
    d, h, kv, hd = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                    cfg.resolved_head_dim)
    hp = padded_heads(cfg)
    ks = jax.random.split(key, 4)
    s = 1.0 / d ** 0.5
    so = 1.0 / (h * hd) ** 0.5
    wq = jax.random.normal(ks[0], (d, h, hd), dtype) * s
    wo = jax.random.normal(ks[3], (h, hd, d), dtype) * so
    if hp != h:
        # zero pad slices: padded heads emit exactly 0 through wo and are
        # frozen at use => function identical to the unpadded arch, but
        # the head dim now shards over the model axis.
        wq = jnp.concatenate(
            [wq, jnp.zeros((d, hp - h, hd), dtype)], axis=1)
        wo = jnp.concatenate(
            [wo, jnp.zeros((hp - h, hd, d), dtype)], axis=0)
    return {
        "wq": wq,
        "wk": jax.random.normal(ks[1], (d, kv, hd), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, kv, hd), dtype) * s,
        "wo": wo,
    }


def _freeze_pad(w, n_real: int, axis: int):
    """stop_gradient on the pad slice so padded heads stay exactly 0."""
    real = jax.lax.slice_in_dim(w, 0, n_real, axis=axis)
    pad = jax.lax.slice_in_dim(w, n_real, w.shape[axis], axis=axis)
    return jnp.concatenate([real, jax.lax.stop_gradient(pad)], axis=axis)


def attention_param_specs():
    return {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }


def _repeat_kv(x: jnp.ndarray, groups: int) -> jnp.ndarray:
    """[B, S, KV, H] -> [B, S, KV*groups, H] (GQA head expansion)."""
    if groups == 1:
        return x
    b, s, kv, h = x.shape
    return jnp.broadcast_to(
        x[:, :, :, None, :], (b, s, kv, groups, h)
    ).reshape(b, s, kv * groups, h)


def _expand_kv_padded(x: jnp.ndarray, groups: int, n_real: int,
                      hp: int) -> jnp.ndarray:
    """GQA expansion to hp heads: real head h uses kv[h // groups];
    padded heads (q == 0 anyway) read kv[0]."""
    idx = [min(h_ // groups, x.shape[2] - 1) if h_ < n_real else 0
           for h_ in range(hp)]
    return jnp.take(x, jnp.asarray(idx, dtype=jnp.int32), axis=2)


def _mask(q_pos, k_pos, window: Optional[int]):
    """Causal (+ sliding window) mask: [..., Sq, Sk] bool (True = keep)."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        m &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return m


def dense_attention(q, k, v, q_pos, k_pos, window=None):
    """Reference O(S²) attention. q: [B,Sq,H,D], k/v: [B,Sk,H,D]."""
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    mask = _mask(q_pos, k_pos, window)[:, None]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def blocked_attention(q, k, v, q_pos, k_pos, window=None,
                      q_block=512, kv_block=1024, causal_skip=False,
                      score_dtype=jnp.float32):
    """Flash-style attention: scan over q blocks; online softmax over kv
    blocks. Memory O(B·H·q_block·kv_block). Shapes as dense_attention."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    q_block = min(q_block, sq)
    kv_block = min(kv_block, sk)
    assert sq % q_block == 0 and sk % kv_block == 0
    nq, nk = sq // q_block, sk // kv_block
    scale = hd ** -0.5

    qs = q.reshape(b, nq, q_block, h, hd).transpose(1, 0, 2, 3, 4)
    qp = q_pos.reshape(b, nq, q_block).transpose(1, 0, 2)
    ks_ = k.reshape(b, nk, kv_block, h, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, nk, kv_block, h, hd).transpose(1, 0, 2, 3, 4)
    kp = k_pos.reshape(b, nk, kv_block).transpose(1, 0, 2)

    def q_step(_, q_in):
        qi, qpi = q_in                                  # [B,qb,H,D], [B,qb]

        def kv_step(carry, kv_in):
            acc, m_run, l_run = carry
            kj, vj, kpj = kv_in

            s_ij = (jnp.einsum("bqhd,bkhd->bhqk", qi, kj,
                            preferred_element_type=score_dtype)
                    .astype(jnp.float32) * scale)
            # Barrier: the mask depends only on position vectors, and
            # XLA's scan "wide" pass would otherwise precompute and STORE
            # the [B,H,qb,kb] mask for every (iq,ik) pair — gigabytes of
            # pred traffic. Recompute per step instead.
            qpi_b, kpj_b = jax.lax.optimization_barrier((qpi, kpj))
            msk = _mask(qpi_b, kpj_b, window)[:, None]
            s_ij = jnp.where(msk, s_ij, NEG_INF)

            m_new = jnp.maximum(m_run, jnp.max(s_ij, axis=-1))
            p = jnp.exp(s_ij - m_new[..., None])
            alpha = jnp.exp(m_run - m_new)
            l_new = l_run * alpha + jnp.sum(p, axis=-1)
            acc = (acc * alpha[..., None]
                   + jnp.einsum("bhqk,bkhd->bhqd", p.astype(vj.dtype),
                                vj).astype(jnp.float32))
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((b, h, q_block, hd), jnp.float32)
        m0 = jnp.full((b, h, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_block), jnp.float32)
        # Inherit varying axes from data (shard_map compatibility).
        zero = (qi.astype(jnp.float32).sum() * 0)
        # Flash-style backward: recompute per-block scores/probabilities
        # instead of storing [nq,nk,B,H,qb,kb] f32 across the whole scan
        # (which costs ~8 GB/layer of residual traffic at 4k).
        kv_step_ck = jax.checkpoint(kv_step)
        (acc, _, l), _ = jax.lax.scan(
            kv_step_ck, (acc0 + zero, m0 + zero, l0 + zero), (ks_, vs, kp))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.transpose(0, 2, 1, 3).astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (qs, qp))       # [nq,B,qb,H,D]
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)


class KVCache(NamedTuple):
    k: jnp.ndarray        # [B, S_max, KV, H]
    v: jnp.ndarray        # [B, S_max, KV, H]
    length: jnp.ndarray   # [B] int32 — tokens filled

    @classmethod
    def init(cls, batch: int, max_len: int, kv_heads: int, head_dim: int,
             dtype) -> "KVCache":
        return cls(
            k=jnp.zeros((batch, max_len, kv_heads, head_dim), dtype),
            v=jnp.zeros((batch, max_len, kv_heads, head_dim), dtype),
            length=jnp.zeros((batch,), jnp.int32),
        )


#: seq axis of the K/V arrays counted from the END (leading dims vary:
#: [B, S, KV, H] per layer, [G, B, S, KV, H] stacked over scan groups).
KV_SEQ_AXIS = -3


def kv_block_slice(cache: KVCache, t0: int, t1: int
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Token block ``[t0, t1)`` of a cache — the unit the paged serving
    cache (``repro.serving.kv_cache``) evicts/encodes. Works on a
    per-layer cache or the group-stacked decode-states leaf."""
    sl = (Ellipsis, slice(t0, t1)) + (slice(None),) * (-KV_SEQ_AXIS - 1)
    return cache.k[sl], cache.v[sl]


def kv_block_restore(cache: KVCache, t0: int, t1: int,
                     k: jnp.ndarray, v: jnp.ndarray) -> KVCache:
    """Write block ``[t0, t1)`` back into the cache (decode-on-access
    epilogue of the paged cache) — inverse of :func:`kv_block_slice`."""
    sl = (Ellipsis, slice(t0, t1)) + (slice(None),) * (-KV_SEQ_AXIS - 1)
    return cache._replace(k=cache.k.at[sl].set(k.astype(cache.k.dtype)),
                          v=cache.v.at[sl].set(v.astype(cache.v.dtype)))


def attention_block(params, x, cfg: ModelConfig, positions,
                    cache: Optional[KVCache] = None):
    """Self-attention (training/prefill) or single-token decode.

    x: [B, S, D]. With ``cache``, S==1 decode: append to cache, attend
    over the filled prefix. Returns (out [B,S,D], new_cache|None).
    """
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    groups = h // kv
    hp = padded_heads(cfg)

    wq, wo = params["wq"], params["wo"]
    if hp != h:
        wq = _freeze_pad(wq, h, 1)
        wo = _freeze_pad(wo, h, 0)
    q = jnp.einsum("bsd,dnh->bsnh", x, wq.astype(x.dtype))
    k = jnp.einsum("bsd,dnh->bsnh", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dnh->bsnh", x, params["wv"].astype(x.dtype))
    q = layers.apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
    k = layers.apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    q = logical_constraint(q, ("batch", "seq", "heads", "head_dim"))
    k = logical_constraint(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = logical_constraint(v, ("batch", "seq", "kv_heads", "head_dim"))

    if cache is not None:
        # Decode: write this token at position `length`, attend to prefix.
        b, s_in = x.shape[0], x.shape[1]
        idx = cache.length                                   # [B]
        if s_in == 1:
            # Mask-based write: elementwise, so it stays local when the
            # cache's seq dim is sharded (kv_seq -> model/data rules) —
            # a dynamic-update-slice would force a gather under GSPMD.
            s_max = cache.k.shape[1]
            pos_iota = jnp.arange(s_max, dtype=jnp.int32)[None, :, None,
                                                          None]
            writing = pos_iota == idx[:, None, None, None]   # [B,S,1,1]
            k_new = jnp.where(writing, k.astype(cache.k.dtype), cache.k)
            v_new = jnp.where(writing, v.astype(cache.v.dtype), cache.v)
        else:
            # Multi-token prefill into the cache (small-scale serving).
            k_new = jax.vmap(
                lambda ck, kn, i: jax.lax.dynamic_update_slice(
                    ck, kn.astype(ck.dtype), (i, 0, 0)))(cache.k, k, idx)
            v_new = jax.vmap(
                lambda cv, vn, i: jax.lax.dynamic_update_slice(
                    cv, vn.astype(cv.dtype), (i, 0, 0)))(cache.v, v, idx)
        new_cache = KVCache(k=k_new, v=v_new, length=idx + s_in)

        q = q[:, :, :h]  # decode path runs unpadded (cache is small)
        # GQA-grouped flash-decode: contract against the cache PER
        # KV-HEAD (no head expansion). The cache's seq dim stays sharded
        # (kv_seq rules); partial scores are shard-local and only the
        # tiny softmax statistics / output reductions cross shards —
        # expanding kv to q-heads instead forces a full f32 cache
        # all-gather (measured: 15 GB/step on chatglm3 decode_32k).
        s_max = k_new.shape[1]
        qg = q.reshape(b, q.shape[1], kv, groups, hd)
        k_pos = jnp.arange(s_max, dtype=jnp.int32)
        scale = hd ** -0.5
        scores = (jnp.einsum("bqkgd,bskd->bqkgs", qg, k_new)
                  .astype(jnp.float32) * scale)       # [B,1,KV,G,S]
        valid = (k_pos[None, None, None, None, :]
                 <= positions[:, :, None, None, None])
        if cfg.sliding_window is not None:
            valid &= (k_pos[None, None, None, None, :]
                      > positions[:, :, None, None, None]
                      - cfg.sliding_window)
        scores = jnp.where(valid, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bqkgs,bskd->bqkgd",
                         probs.astype(x.dtype), v_new)
        out = out.reshape(b, q.shape[1], h, hd)
    else:
        if hp != h:
            kk = _expand_kv_padded(k, groups, h, hp)
            vv = _expand_kv_padded(v, groups, h, hp)
        else:
            kk = _repeat_kv(k, groups)
            vv = _repeat_kv(v, groups)
        if cfg.attn_impl == "dense":
            out = dense_attention(q, kk, vv, positions, positions,
                                  cfg.sliding_window)
        else:
            out = blocked_attention(
                q, kk, vv, positions, positions, cfg.sliding_window,
                cfg.attn_q_block, cfg.attn_kv_block, cfg.causal_skip,
                score_dtype=jnp.dtype(cfg.attn_score_dtype))
        new_cache = None

    out = logical_constraint(out, ("batch", "seq", "heads", "head_dim"))
    wo_used = wo if out.shape[2] == wo.shape[0] else wo[:out.shape[2]]
    return jnp.einsum("bsnh,nhd->bsd", out,
                      wo_used.astype(out.dtype)), new_cache
