"""Mixture-of-Experts FFN: shared + routed experts, top-k routing with
capacity, scatter/gather dispatch.

Three dispatch implementations (``MoEConfig.impl``, validated against
:data:`SUPPORTED_IMPLS`):

  * ``"gspmd"``: experts stay sharded over the model axis; dispatch is a
    scatter/gather + batched einsum, GSPMD inserts the collectives.
  * ``"grouped_local"``: the same math vmapped over dp-aligned token
    groups so scatters stay shard-local (perf variant — see
    :func:`_moe_grouped`).
  * ``"shardmap_a2a"``: explicit expert-parallel dispatch under a fully
    manual ``shard_map`` — tokens cross the model axis through an
    ``all_to_all``, optionally as QLC-compressed containers (the
    paper's technique applied to MoE traffic). Routing and capacity
    drops are bit-identical to ``"gspmd"`` by construction: each rank
    reconstructs the global arrival-order positions from an integer
    counts all-gather (see :func:`_moe_shardmap_a2a`).

The compressed wire is opened by binding ``moe/dispatch`` /
``moe/combine`` channels (:data:`MOE_DISPATCH` / :data:`MOE_COMBINE`,
calibrated by ``repro.comm.calibrate.calibrate_moe_entries``) with
:func:`bind_moe_channels` around the step's trace. Without bound
channels the a2a runs uncompressed (``lax.all_to_all``), bit-identical
to ``"gspmd"``.
"""
from __future__ import annotations

import contextlib
import threading

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import layers
from repro.parallel import sharding as shd
from repro.parallel.sharding import logical_constraint

#: Registry / channel names of the expert-dispatch wire codecs.
MOE_DISPATCH = "moe/dispatch"
MOE_COMBINE = "moe/combine"

#: ``MoEConfig.impl`` values :func:`moe_block` accepts.
SUPPORTED_IMPLS = ("gspmd", "grouped_local", "shardmap_a2a")


def init_moe(key, cfg: ModelConfig, dtype):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    s_in = 1.0 / d ** 0.5
    s_out = 1.0 / m.d_expert ** 0.5
    p = {
        "router": jax.random.normal(ks[0], (d, m.num_experts),
                                    jnp.float32) * s_in,
        "w_in": jax.random.normal(
            ks[1], (m.num_experts, d, m.d_expert), dtype) * s_in,
        "w_gate": jax.random.normal(
            ks[2], (m.num_experts, d, m.d_expert), dtype) * s_in,
        "w_out": jax.random.normal(
            ks[3], (m.num_experts, m.d_expert, d), dtype) * s_out,
    }
    if m.num_shared_experts:
        p["shared"] = layers.init_mlp(
            ks[4], d, m.num_shared_experts * m.d_expert, "swiglu", dtype)
    return p


def moe_param_specs(cfg: ModelConfig):
    specs = {
        "router": ("embed", "expert"),
        "w_in": ("expert", "embed", "mlp"),
        "w_gate": ("expert", "embed", "mlp"),
        "w_out": ("expert", "mlp", "embed"),
    }
    if cfg.moe and cfg.moe.num_shared_experts:
        specs["shared"] = layers.mlp_param_specs("swiglu")
    return specs


# --------------------------------------------------------------------------
# Routing (ONE router einsum, shared by dispatch and the aux loss)
# --------------------------------------------------------------------------

def _router_logits(params, x_flat: jnp.ndarray) -> jnp.ndarray:
    """x_flat: [N, D] -> router logits [N, E] (f32)."""
    return jnp.einsum("nd,de->ne", x_flat.astype(jnp.float32),
                      params["router"])


def _route(params, x_flat: jnp.ndarray, m: MoEConfig):
    """x_flat: [N, D] -> (expert_idx [N,k], gates [N,k], probs [N,E]).

    ``probs`` is the full softmax over the SAME logits the top-k ran on
    — the aux load-balance loss consumes it without a second router
    einsum (jit dead-code-eliminates it when unused).
    """
    logits = _router_logits(params, x_flat)
    top, idx = jax.lax.top_k(logits, m.top_k)
    gates = jax.nn.softmax(top, axis=-1)
    probs = jax.nn.softmax(logits, axis=-1)
    return idx, gates, probs


def aux_load_balance_loss(probs: jnp.ndarray, idx: jnp.ndarray,
                          m: MoEConfig) -> jnp.ndarray:
    """Switch-style load-balancing auxiliary loss from precomputed
    routing artifacts (``probs``/``idx`` as returned by :func:`_route`)
    — the router einsum is shared with dispatch, not recomputed."""
    onehot = jax.nn.one_hot(idx, m.num_experts, dtype=jnp.float32).sum(1)
    frac_tokens = onehot.mean(0)
    frac_probs = probs.astype(jnp.float32).mean(0)
    return m.num_experts * jnp.sum(frac_tokens * frac_probs)


# --------------------------------------------------------------------------
# Shared dispatch-plan / FFN helpers
# --------------------------------------------------------------------------

def _capacity(n_tokens: int, m: MoEConfig) -> int:
    """Static per-expert buffer capacity for ``n_tokens`` routed tokens."""
    return max(1, int(n_tokens * m.top_k * m.capacity_factor
                      // m.num_experts))


def _positions_in_expert(flat_e: jnp.ndarray, num_experts: int):
    """Arrival-order position of each assignment within its expert
    (pre-capacity). ``flat_e [A]`` -> ``pos [A]`` — assignment *a* is
    the ``pos[a]``-th arrival at expert ``flat_e[a]`` in sequence
    order. Every impl derives its capacity drops from this one
    primitive, which is what makes drops bit-identical across impls."""
    onehot = jax.nn.one_hot(flat_e, num_experts, dtype=jnp.int32)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot
    return jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]


def _expert_ffn(buf: jnp.ndarray, w_in, w_gate, w_out) -> jnp.ndarray:
    """Row-wise swiglu expert FFN on a buffer ``[E, C, D]``. No biases,
    so all-zero rows (padding / other ranks' slots) map to exactly
    zero — the property the expert-parallel path relies on."""
    h = jnp.einsum("ecd,edf->ecf", buf, w_in.astype(buf.dtype))
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(buf.dtype))
    h = jax.nn.silu(g) * h
    return jnp.einsum("ecf,efd->ecd", h, w_out.astype(buf.dtype))


# --------------------------------------------------------------------------
# Channel binding + traffic capture (trace-time context)
# --------------------------------------------------------------------------

_MOE_CTX = threading.local()


@contextlib.contextmanager
def bind_moe_channels(channels):
    """Bind the expert-dispatch wire channels for ``shardmap_a2a``.

    ``channels`` maps :data:`MOE_DISPATCH` / :data:`MOE_COMBINE` to
    :class:`~repro.comm.channel.Channel` objects bound to the
    ``"model"`` axis. Enter this around the code that TRACES the loss
    (the step builders in ``repro.training.train_step`` do it for you
    via their ``moe_channels`` argument) — the binding is consulted at
    trace time, inside the expert ``shard_map``.

    ``repro.adaptive.AdaptiveChannel`` wrappers (see
    :func:`adaptive_moe_channels`) work here unchanged — attribute
    forwarding resolves the deployed codec at trace time. Because the
    binding is baked into the traced step, a codec hot-swap only
    reaches the expert wire after the step is REBUILT
    (``TrainingAdapter`` does exactly that for the training loop).
    """
    old = getattr(_MOE_CTX, "channels", None)
    _MOE_CTX.channels = channels
    try:
        yield
    finally:
        _MOE_CTX.channels = old


def bound_moe_channels():
    """The currently bound ``{name: Channel}`` map, or ``None``."""
    return getattr(_MOE_CTX, "channels", None)


def adaptive_moe_channels(controller, channels):
    """Wrap a ``{name: Channel}`` expert-wire map for codec hot-swap.

    Each channel is registered with the
    :class:`repro.adaptive.AdaptiveController` under its registry name
    (:data:`MOE_DISPATCH` / :data:`MOE_COMBINE`), so a drift-triggered
    ``register_revision`` atomically rebinds the map in place; rebuild
    the traced step afterwards to put the new codec on the wire.
    """
    return {name: controller.wrap(ch, name=name)
            for name, ch in channels.items()}


@contextlib.contextmanager
def capture_moe_traffic(out_list: list):
    """Capture each MoE layer's eager-mode ``(params, x)`` at
    :func:`moe_block` entry into ``out_list`` — the calibration hook
    ``repro.comm.calibrate.calibrate_moe_entries`` uses to see actual
    routed-token traffic. Traced calls are not captured."""
    old = getattr(_MOE_CTX, "capture", None)
    _MOE_CTX.capture = out_list
    try:
        yield out_list
    finally:
        _MOE_CTX.capture = old


def dispatch_traffic(params, x: jnp.ndarray, cfg: ModelConfig):
    """The per-layer expert-wire traffic: ``(dispatch buffer [E, C, D],
    combine buffer [E, C, D])`` of one MoE layer on input ``x`` — the
    token values entering / leaving the expert ``all_to_all``.
    Impl-independent (the gspmd dispatch math); calibration input."""
    m = cfg.moe
    b, s, d = x.shape
    n = b * s
    x_flat = x.reshape(n, d)
    idx, _gates, _probs = _route(params, x_flat, m)
    capacity = _capacity(n, m)
    flat_e = idx.reshape(-1)
    pos = _positions_in_expert(flat_e, m.num_experts)
    keep = pos < capacity
    slot = jnp.where(keep, flat_e * capacity
                     + jnp.minimum(pos, capacity - 1),
                     m.num_experts * capacity)
    tok_idx = jnp.repeat(jnp.arange(n), m.top_k)
    buf = jnp.zeros((m.num_experts * capacity, d), x.dtype)
    buf = buf.at[slot].set(x_flat[tok_idx], mode="drop")
    buf = buf.reshape(m.num_experts, capacity, d)
    out_e = _expert_ffn(buf, params["w_in"], params["w_gate"],
                        params["w_out"])
    return buf, out_e


# --------------------------------------------------------------------------
# Dispatch implementations
# --------------------------------------------------------------------------

def moe_block(params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """x: [B, S, D] -> [B, S, D]. Capacity-bounded top-k dispatch."""
    impl = cfg.moe.impl
    if impl not in SUPPORTED_IMPLS:
        raise ValueError(
            f"unknown MoEConfig.impl {impl!r}; supported impls are "
            f"{SUPPORTED_IMPLS}")
    cap = getattr(_MOE_CTX, "capture", None)
    if cap is not None and not isinstance(x, jax.core.Tracer):
        cap.append((params, x))
    if impl == "grouped_local":
        return _moe_grouped(params, x, cfg)
    if impl == "shardmap_a2a":
        return _moe_shardmap_a2a(params, x, cfg)
    m = cfg.moe
    b, s, d = x.shape
    n = b * s
    x_flat = x.reshape(n, d)

    idx, gates, _probs = _route(params, x_flat, m)     # [N,k], [N,k]
    capacity = _capacity(n, m)

    # Position of each (token, k) assignment within its expert's buffer.
    flat_e = idx.reshape(-1)                          # [N*k]
    pos = _positions_in_expert(flat_e, m.num_experts)
    keep = pos < capacity
    slot = flat_e * capacity + jnp.minimum(pos, capacity - 1)  # [N*k]
    slot = jnp.where(keep, slot, m.num_experts * capacity)     # drop slot

    # Scatter tokens into expert buffers [E*C, D] (dropped -> discarded).
    tok_idx = jnp.repeat(jnp.arange(n), m.top_k)
    buf = jnp.zeros((m.num_experts * capacity, d), x.dtype)
    buf = buf.at[slot].set(x_flat[tok_idx], mode="drop")
    buf = buf.reshape(m.num_experts, capacity, d)
    buf = logical_constraint(buf, ("expert", None, "embed"))

    # Batched expert FFN (einsum over the expert dim; GSPMD shards it).
    out_e = _expert_ffn(buf, params["w_in"], params["w_gate"],
                        params["w_out"])
    out_e = out_e.reshape(m.num_experts * capacity, d)

    # Gather back and combine with gate weights.
    gathered = jnp.take(out_e, jnp.minimum(slot, out_e.shape[0] - 1), axis=0)
    gathered = jnp.where(keep[:, None], gathered, 0)
    weighted = gathered * gates.reshape(-1)[:, None].astype(x.dtype)
    out = jnp.zeros((n, d), x.dtype).at[tok_idx].add(weighted)

    if m.num_shared_experts:
        out = out + layers.mlp(params["shared"], x, "swiglu").reshape(n, d)
    return out.reshape(b, s, d)


def _moe_grouped(params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Grouped-local dispatch (perf variant, DESIGN.md / EXPERIMENTS §Perf).

    The global-buffer dispatch scatters batch-sharded tokens into an
    expert buffer whose sharding doesn't match — GSPMD lowers that to
    zeros + local scatter + ALL-REDUCE of the whole buffer (terabytes
    for mixtral train). Here tokens are split into ``dispatch_groups``
    groups aligned with the dp sharding; capacity is per (group,
    expert); scatters and gathers stay inside a group (= inside a
    shard), and the only cross-device traffic left is the inherent
    expert-TP all-reduce of the FFN outputs.
    """
    m = cfg.moe
    b, s, d = x.shape
    n = b * s
    g = min(m.dispatch_groups, n)
    while n % g:
        g -= 1
    ng = n // g
    x_flat = x.reshape(n, d)

    idx, gates, _probs = _route(params, x_flat, m)       # [N,k]
    capacity = _capacity(ng, m)
    xg = x_flat.reshape(g, ng, d)
    idx_g = idx.reshape(g, ng, m.top_k)
    gates_g = gates.reshape(g, ng, m.top_k).astype(x.dtype)
    tok_idx = jnp.repeat(jnp.arange(ng), m.top_k)

    def dispatch(xl, il):
        flat_e = il.reshape(-1)                           # [ng*k]
        pos = _positions_in_expert(flat_e, m.num_experts)
        keep = pos < capacity
        slot = flat_e * capacity + jnp.minimum(pos, capacity - 1)
        slot = jnp.where(keep, slot, m.num_experts * capacity)
        buf = jnp.zeros((m.num_experts * capacity, d), xl.dtype)
        buf = buf.at[slot].set(xl[tok_idx], mode="drop")
        return buf.reshape(m.num_experts, capacity, d), slot, keep

    bufs, slots, keeps = jax.vmap(dispatch)(xg, idx_g)
    bufs = logical_constraint(bufs, ("batch", "expert", None, "embed"))

    h = jnp.einsum("gecd,edf->gecf", bufs, params["w_in"].astype(x.dtype))
    gt = jnp.einsum("gecd,edf->gecf", bufs,
                    params["w_gate"].astype(x.dtype))
    h = jax.nn.silu(gt) * h
    h = logical_constraint(h, ("batch", "expert", None, "mlp"))
    out_e = jnp.einsum("gecf,efd->gecd", h,
                       params["w_out"].astype(x.dtype))
    out_e = out_e.reshape(g, m.num_experts * capacity, d)

    def combine(oe, slot, keep, gl):
        gathered = jnp.take(oe, jnp.minimum(slot, oe.shape[0] - 1), axis=0)
        gathered = jnp.where(keep[:, None], gathered, 0)
        weighted = gathered * gl.reshape(-1)[:, None]
        return jnp.zeros((ng, d), oe.dtype).at[tok_idx].add(weighted)

    out = jax.vmap(combine)(out_e, slots, keeps, gates_g).reshape(n, d)

    if m.num_shared_experts:
        out = out + layers.mlp(params["shared"], x, "swiglu").reshape(n, d)
    return out.reshape(b, s, d)


# --------------------------------------------------------------------------
# Expert-parallel shard_map all_to_all dispatch
# --------------------------------------------------------------------------

def shardmap_a2a_geometry(cfg: ModelConfig, n_tokens: int, mesh) -> dict:
    """Static per-rank a2a payload geometry of one MoE layer.

    Returns ``{"ng", "capacity", "c_send", "row_values", "axis_size"}``:
    each rank's all_to_all moves ``axis_size`` rows of ``row_values``
    f32 values (per direction, per layer) for ``ng`` local tokens.
    """
    m = cfg.moe
    dm = int(mesh.shape["model"])
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= int(mesh.shape[a])
    shards = dp * dm
    if n_tokens % shards:
        raise ValueError(
            f"shardmap_a2a needs the token count ({n_tokens}) divisible "
            f"by the token shards (dp*model = {shards})")
    if m.num_experts % dm:
        raise ValueError(
            f"shardmap_a2a needs num_experts ({m.num_experts}) divisible "
            f"by the model axis ({dm})")
    ng = n_tokens // shards
    capacity = _capacity(n_tokens, m)
    # top_k experts are distinct per token, so a rank sends at most
    # min(ng, capacity) rows to any one expert — the static send bound.
    c_send = min(ng, capacity)
    return {"ng": ng, "capacity": capacity, "c_send": c_send,
            "row_values": (m.num_experts // dm) * c_send * cfg.d_model,
            "axis_size": dm}


def _raw_a2a(axis: str):
    def a2a(v):
        return jax.lax.all_to_all(v, axis, split_axis=0, concat_axis=0,
                                  tiled=True)
    return a2a


def _channel_a2a(ch, axis: str):
    """Compressed a2a as a straight-through ``custom_vjp``.

    Forward moves the activations as QLC containers
    (``Channel.all_to_all``); the QLC coding is lossless on the e4m3
    symbols, but the integer encode/decode has no gradient, so the
    backward pass routes the cotangent through the raw ``all_to_all``
    (its own transpose). Gradient-wire compression is the train step's
    separate reduce-scatter subsystem — activations-forward is where
    the expert bandwidth bound lives.
    """
    raw = _raw_a2a(axis)

    def wire(v):
        vals, _ok = ch.all_to_all(v)
        return vals.astype(v.dtype)

    f = jax.custom_vjp(wire)

    def fwd(v):
        return wire(v), None

    def bwd(_res, g):
        return (raw(g),)

    f.defvjp(fwd, bwd)
    return f


def _moe_shardmap_a2a(params, x: jnp.ndarray,
                      cfg: ModelConfig) -> jnp.ndarray:
    """Expert-parallel dispatch under a fully-manual ``shard_map``.

    Tokens are sharded contiguously over (pod?, data?, model) on their
    leading dim, experts over the model axis. Per rank:

    1. route the local ``ng`` tokens (replicated router — per-token,
       so identical to global routing);
    2. ``all_gather`` the per-expert assignment COUNTS (int32, never
       the values) in rank-major order and prefix-sum them — rank r's
       exclusive offset into each expert's global arrival order. Since
       global token order is rank-major, ``offset[e] + pos_local``
       IS gspmd's global cumsum position, so ``keep = pos_global <
       capacity`` reproduces its capacity drops bit for bit — and each
       rank's kept assignments are a PREFIX of its local arrival
       order, so send slots pack contiguously and the receiver
       reconstructs global positions from the counts alone (no index
       metadata on the value wire);
    3. ``all_to_all`` the packed ``[axis_size, E_local, C_send, D]``
       send buffer over the model axis — raw, or as QLC containers
       when :func:`bind_moe_channels` provided channels;
    4. scatter received rows at their reconstructed global positions
       (disjoint across sources — exact), run the local experts' FFN
       (zero rows stay zero: no biases), gather the same positions
       back and reverse the a2a;
    5. combine with gate weights on the local tokens.

    Only the model-axis a2a moves values; dp groups exchange nothing
    but the counts gather. The escape-pool ``ok`` flag is not surfaced:
    the empirically-calibrated plans size pools for the measured escape
    rate, and CI asserts value-identity of the compressed wire against
    its raw-e4m3 twin.
    """
    m = cfg.moe
    mesh = shd._current_mesh()
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        raise ValueError(
            "moe.impl='shardmap_a2a' needs a mesh with a 'model' axis in "
            "scope (repro.parallel.sharding.use_mesh)")
    b, s, d = x.shape
    n = b * s
    geo = shardmap_a2a_geometry(cfg, n, mesh)
    dm, ng, capacity, c_send = (geo["axis_size"], geo["ng"],
                                geo["capacity"], geo["c_send"])
    el = m.num_experts // dm                       # local experts
    token_axes = tuple(a for a in ("pod", "data")
                       if a in mesh.axis_names) + ("model",)
    channels = bound_moe_channels()
    if channels is not None:
        dispatch_a2a = _channel_a2a(channels[MOE_DISPATCH], "model")
        combine_a2a = _channel_a2a(channels[MOE_COMBINE], "model")
    else:
        dispatch_a2a = combine_a2a = _raw_a2a("model")

    def body(xl, router, w_in, w_gate, w_out):
        # xl [ng, D] local token chunk; w_* [el, ...] local experts.
        idx, gates, _probs = _route({"router": router}, xl, m)
        flat_e = idx.reshape(-1)                   # [ng*k]
        pos_local = _positions_in_expert(flat_e, m.num_experts)
        counts = jax.nn.one_hot(flat_e, m.num_experts,
                                dtype=jnp.int32).sum(0)          # [E]

        # Rank-major counts gather: innermost token axis first, so
        # reshape(-1, E) indexes ranks in global token order.
        g = counts
        for ax in reversed(token_axes):
            g = jax.lax.all_gather(g, ax)
        g = g.reshape(-1, m.num_experts)           # [R, E]
        offsets = jnp.cumsum(g, axis=0) - g        # exclusive prefix

        r_me = jnp.int32(0)
        for ax in token_axes:
            r_me = r_me * mesh.shape[ax] + jax.lax.axis_index(ax)
        off_me = jax.lax.dynamic_index_in_dim(offsets, r_me, axis=0,
                                              keepdims=False)    # [E]

        # Bit-identical global capacity drops (gspmd's cumsum order).
        pos_global = off_me[flat_e] + pos_local
        keep = pos_global < capacity

        # Pack kept assignments: their local positions are a prefix per
        # expert, so pos_local IS the send slot.
        tok_idx = jnp.repeat(jnp.arange(ng), m.top_k)
        slot = flat_e * c_send + jnp.minimum(pos_local, c_send - 1)
        slot = jnp.where(keep, slot, m.num_experts * c_send)
        sbuf = jnp.zeros((m.num_experts * c_send, d), xl.dtype)
        sbuf = sbuf.at[slot].set(xl[tok_idx], mode="drop")
        sbuf = sbuf.reshape(dm, el, c_send, d)     # dest-major rows

        recv = dispatch_a2a(sbuf)                  # [dm, el, c_send, D]

        # Reconstruct each source's global positions for MY experts
        # from the counts gather (my model-group peers share my
        # (pod, data) coordinates: flat ranks [base, base + dm)).
        base = (r_me // dm) * dm
        my_model = jax.lax.axis_index("model")
        off_grp = jax.lax.dynamic_slice(
            offsets, (base, my_model * el), (dm, el))            # [dm, el]
        cnt_grp = jax.lax.dynamic_slice(
            g, (base, my_model * el), (dm, el))
        kept_grp = jnp.clip(capacity - off_grp, 0, cnt_grp)
        s_idx = jnp.arange(c_send)[None, None, :]
        valid = s_idx < kept_grp[:, :, None]       # [dm, el, c_send]
        e_idx = jnp.broadcast_to(jnp.arange(el)[None, :, None],
                                 valid.shape)
        rpos = jnp.where(valid,
                         e_idx * capacity + off_grp[:, :, None] + s_idx,
                         el * capacity)            # drop slot
        rbuf = jnp.zeros((el * capacity, d), xl.dtype)
        rbuf = rbuf.at[rpos.reshape(-1)].set(
            recv.reshape(-1, d).astype(xl.dtype), mode="drop")
        rbuf = rbuf.reshape(el, capacity, d)

        out_local = _expert_ffn(rbuf, w_in, w_gate, w_out)

        # Gather the same positions back and reverse the exchange.
        gathered = jnp.take(
            out_local.reshape(el * capacity, d),
            jnp.minimum(rpos.reshape(-1), el * capacity - 1), axis=0)
        gathered = jnp.where(valid.reshape(-1)[:, None], gathered, 0)
        back = combine_a2a(gathered.reshape(dm, el, c_send, d))
        back = back.reshape(m.num_experts * c_send, d)

        # Per-assignment combine on the local tokens (gspmd's gather).
        comb = jnp.take(back, jnp.minimum(slot, back.shape[0] - 1),
                        axis=0)
        comb = jnp.where(keep[:, None], comb, 0)
        weighted = comb * gates.reshape(-1)[:, None].astype(xl.dtype)
        return jnp.zeros((ng, d), xl.dtype).at[tok_idx].add(weighted)

    tok_spec = jax.sharding.PartitionSpec(token_axes)
    rep = jax.sharding.PartitionSpec()
    exp = jax.sharding.PartitionSpec("model")
    out = shd.shard_map_compat(
        body, mesh=mesh,
        in_specs=(tok_spec, rep, exp, exp, exp),
        out_specs=tok_spec,
    )(x.reshape(n, d), params["router"], params["w_in"],
      params["w_gate"], params["w_out"])

    if m.num_shared_experts:
        out = out + layers.mlp(params["shared"], x, "swiglu").reshape(n, d)
    return out.reshape(b, s, d)
