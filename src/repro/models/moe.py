"""Mixture-of-Experts FFN: shared + routed experts, top-k routing with
capacity, scatter/gather dispatch.

Two dispatch implementations:
  * "gspmd": experts stay sharded over the model axis; dispatch is a
    scatter/gather + batched einsum, GSPMD inserts the collectives.
  * "shardmap_a2a": explicit all_to_all dispatch usable under shard_map,
    with optional QLC compression of the dispatched activations (the
    paper's technique applied to MoE traffic).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import layers
from repro.parallel.sharding import logical_constraint


def init_moe(key, cfg: ModelConfig, dtype):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    s_in = 1.0 / d ** 0.5
    s_out = 1.0 / m.d_expert ** 0.5
    p = {
        "router": jax.random.normal(ks[0], (d, m.num_experts),
                                    jnp.float32) * s_in,
        "w_in": jax.random.normal(
            ks[1], (m.num_experts, d, m.d_expert), dtype) * s_in,
        "w_gate": jax.random.normal(
            ks[2], (m.num_experts, d, m.d_expert), dtype) * s_in,
        "w_out": jax.random.normal(
            ks[3], (m.num_experts, m.d_expert, d), dtype) * s_out,
    }
    if m.num_shared_experts:
        p["shared"] = layers.init_mlp(
            ks[4], d, m.num_shared_experts * m.d_expert, "swiglu", dtype)
    return p


def moe_param_specs(cfg: ModelConfig):
    specs = {
        "router": ("embed", "expert"),
        "w_in": ("expert", "embed", "mlp"),
        "w_gate": ("expert", "embed", "mlp"),
        "w_out": ("expert", "mlp", "embed"),
    }
    if cfg.moe and cfg.moe.num_shared_experts:
        specs["shared"] = layers.mlp_param_specs("swiglu")
    return specs


def _route(params, x_flat: jnp.ndarray, m: MoEConfig):
    """x_flat: [N, D] -> (expert_idx [N,k], gates [N,k])."""
    logits = jnp.einsum("nd,de->ne", x_flat.astype(jnp.float32),
                        params["router"])
    gates, idx = jax.lax.top_k(logits, m.top_k)
    gates = jax.nn.softmax(gates, axis=-1)
    return idx, gates


def aux_load_balance_loss(params, x_flat, m: MoEConfig) -> jnp.ndarray:
    """Switch-style load-balancing auxiliary loss."""
    logits = jnp.einsum("nd,de->ne", x_flat.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    _, idx = jax.lax.top_k(logits, m.top_k)
    onehot = jax.nn.one_hot(idx, m.num_experts, dtype=jnp.float32).sum(1)
    frac_tokens = onehot.mean(0)
    frac_probs = probs.mean(0)
    return m.num_experts * jnp.sum(frac_tokens * frac_probs)


def moe_block(params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """x: [B, S, D] -> [B, S, D]. Capacity-bounded top-k dispatch."""
    if cfg.moe.impl == "grouped_local":
        return _moe_grouped(params, x, cfg)
    m = cfg.moe
    b, s, d = x.shape
    n = b * s
    x_flat = x.reshape(n, d)

    idx, gates = _route(params, x_flat, m)            # [N,k], [N,k]
    capacity = max(1, int(n * m.top_k * m.capacity_factor // m.num_experts))

    # Position of each (token, k) assignment within its expert's buffer.
    flat_e = idx.reshape(-1)                          # [N*k]
    onehot = jax.nn.one_hot(flat_e, m.num_experts, dtype=jnp.int32)
    pos_in_e = (jnp.cumsum(onehot, axis=0) - onehot)  # [N*k, E]
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = pos < capacity
    slot = flat_e * capacity + jnp.minimum(pos, capacity - 1)  # [N*k]
    slot = jnp.where(keep, slot, m.num_experts * capacity)     # drop slot

    # Scatter tokens into expert buffers [E*C, D] (dropped -> discarded).
    tok_idx = jnp.repeat(jnp.arange(n), m.top_k)
    buf = jnp.zeros((m.num_experts * capacity, d), x.dtype)
    buf = buf.at[slot].set(x_flat[tok_idx], mode="drop")
    buf = buf.reshape(m.num_experts, capacity, d)
    buf = logical_constraint(buf, ("expert", None, "embed"))

    # Batched expert FFN (einsum over the expert dim; GSPMD shards it).
    h = jnp.einsum("ecd,edf->ecf", buf, params["w_in"].astype(buf.dtype))
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(buf.dtype))
    h = jax.nn.silu(g) * h
    out_e = jnp.einsum("ecf,efd->ecd", h, params["w_out"].astype(buf.dtype))
    out_e = out_e.reshape(m.num_experts * capacity, d)

    # Gather back and combine with gate weights.
    gathered = jnp.take(out_e, jnp.minimum(slot, out_e.shape[0] - 1), axis=0)
    gathered = jnp.where(keep[:, None], gathered, 0)
    weighted = gathered * gates.reshape(-1)[:, None].astype(x.dtype)
    out = jnp.zeros((n, d), x.dtype).at[tok_idx].add(weighted)

    if m.num_shared_experts:
        out = out + layers.mlp(params["shared"], x, "swiglu").reshape(n, d)
    return out.reshape(b, s, d)


def _moe_grouped(params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Grouped-local dispatch (perf variant, DESIGN.md / EXPERIMENTS §Perf).

    The global-buffer dispatch scatters batch-sharded tokens into an
    expert buffer whose sharding doesn't match — GSPMD lowers that to
    zeros + local scatter + ALL-REDUCE of the whole buffer (terabytes
    for mixtral train). Here tokens are split into ``dispatch_groups``
    groups aligned with the dp sharding; capacity is per (group,
    expert); scatters and gathers stay inside a group (= inside a
    shard), and the only cross-device traffic left is the inherent
    expert-TP all-reduce of the FFN outputs.
    """
    m = cfg.moe
    b, s, d = x.shape
    n = b * s
    g = min(m.dispatch_groups, n)
    while n % g:
        g -= 1
    ng = n // g
    x_flat = x.reshape(n, d)

    idx, gates = _route(params, x_flat, m)               # [N,k]
    capacity = max(1, int(ng * m.top_k * m.capacity_factor
                          // m.num_experts))
    xg = x_flat.reshape(g, ng, d)
    idx_g = idx.reshape(g, ng, m.top_k)
    gates_g = gates.reshape(g, ng, m.top_k).astype(x.dtype)
    tok_idx = jnp.repeat(jnp.arange(ng), m.top_k)

    def dispatch(xl, il):
        flat_e = il.reshape(-1)                           # [ng*k]
        onehot = jax.nn.one_hot(flat_e, m.num_experts, dtype=jnp.int32)
        pos_in_e = jnp.cumsum(onehot, axis=0) - onehot
        pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
        keep = pos < capacity
        slot = flat_e * capacity + jnp.minimum(pos, capacity - 1)
        slot = jnp.where(keep, slot, m.num_experts * capacity)
        buf = jnp.zeros((m.num_experts * capacity, d), xl.dtype)
        buf = buf.at[slot].set(xl[tok_idx], mode="drop")
        return buf.reshape(m.num_experts, capacity, d), slot, keep

    bufs, slots, keeps = jax.vmap(dispatch)(xg, idx_g)
    bufs = logical_constraint(bufs, ("batch", "expert", None, "embed"))

    h = jnp.einsum("gecd,edf->gecf", bufs, params["w_in"].astype(x.dtype))
    gt = jnp.einsum("gecd,edf->gecf", bufs,
                    params["w_gate"].astype(x.dtype))
    h = jax.nn.silu(gt) * h
    h = logical_constraint(h, ("batch", "expert", None, "mlp"))
    out_e = jnp.einsum("gecf,efd->gecd", h,
                       params["w_out"].astype(x.dtype))
    out_e = out_e.reshape(g, m.num_experts * capacity, d)

    def combine(oe, slot, keep, gl):
        gathered = jnp.take(oe, jnp.minimum(slot, oe.shape[0] - 1), axis=0)
        gathered = jnp.where(keep[:, None], gathered, 0)
        weighted = gathered * gl.reshape(-1)[:, None]
        return jnp.zeros((ng, d), oe.dtype).at[tok_idx].add(weighted)

    out = jax.vmap(combine)(out_e, slots, keeps, gates_g).reshape(n, d)

    if m.num_shared_experts:
        out = out + layers.mlp(params["shared"], x, "swiglu").reshape(n, d)
    return out.reshape(b, s, d)
