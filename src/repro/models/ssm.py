"""State-space / recurrent blocks: Mamba (jamba), sLSTM + mLSTM (xLSTM).

Training/prefill runs a lax.scan over time (associative-scan-able, but
the sequential scan is the clear reference; chunked parallel scan is a
perf option). Decode is O(1) per token from a carried state — these are
the sub-quadratic archs that run the long_500k cells.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import logical_constraint


def _zero_like_data(x, shape, dtype=jnp.float32):
    """Zeros that inherit x's varying manual axes (shard_map-safe)."""
    return jnp.zeros(shape, dtype) + (x.astype(dtype).sum() * 0)


# ==========================================================================
# Mamba (selective SSM, mamba-1 style)
# ==========================================================================

class MambaState(NamedTuple):
    ssm: jnp.ndarray    # [B, d_inner, N] running SSM state
    conv: jnp.ndarray   # [B, K-1, d_inner] conv tail


def mamba_dims(cfg: ModelConfig) -> Tuple[int, int]:
    d_inner = 2 * cfg.d_model
    dt_rank = max(1, cfg.d_model // 16)
    return d_inner, dt_rank


def init_mamba(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    di, dtr = mamba_dims(cfg)
    n = cfg.ssm_state_dim
    k = cfg.conv_kernel
    ks = jax.random.split(key, 6)
    s = 1.0 / d ** 0.5
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di), dtype) * s,
        "conv_w": jax.random.normal(ks[1], (k, di), dtype) * (1.0 / k ** 0.5),
        "x_proj": jax.random.normal(ks[2], (di, dtr + 2 * n), dtype)
        * (1.0 / di ** 0.5),
        "dt_proj": jax.random.normal(ks[3], (dtr, di), dtype)
        * (1.0 / dtr ** 0.5),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[5], (di, d), dtype)
        * (1.0 / di ** 0.5),
    }


def mamba_param_specs():
    return {
        "in_proj": ("embed", "mlp"),
        "conv_w": ("conv", "mlp"),
        "x_proj": ("mlp", None),
        "dt_proj": (None, "mlp"),
        "A_log": ("mlp", "state"),
        "D": ("mlp",),
        "out_proj": ("mlp", "embed"),
    }


def mamba_block(params, x: jnp.ndarray, cfg: ModelConfig,
                state: Optional[MambaState] = None):
    """x: [B, S, D]. Returns (out [B,S,D], new_state | None).

    With ``state`` (decode), S must be 1 and the recurrence advances once.
    """
    b, s, d = x.shape
    di, dtr = mamba_dims(cfg)
    n = cfg.ssm_state_dim
    k = cfg.conv_kernel

    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(x.dtype))
    xi, z = jnp.split(xz, 2, axis=-1)                  # [B,S,di] each

    # Depthwise causal conv along time.
    if state is None:
        pad = jnp.zeros((b, k - 1, di), xi.dtype)
        xc = jnp.concatenate([pad, xi], axis=1)
        new_conv_tail = None
    else:
        xc = jnp.concatenate([state.conv.astype(xi.dtype), xi], axis=1)
        new_conv_tail = xc[:, -(k - 1):].astype(jnp.float32)
    conv = sum(xc[:, i:i + s]
               * params["conv_w"][i][None, None].astype(xc.dtype)
               for i in range(k))
    u = jax.nn.silu(conv)                              # [B,S,di]

    # Input-dependent SSM parameters.
    proj = jnp.einsum("bse,ec->bsc", u, params["x_proj"].astype(u.dtype))
    dt_in, bmat, cmat = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt_in, params["dt_proj"])
    ).astype(jnp.float32)                              # [B,S,di]
    a = -jnp.exp(params["A_log"])                      # [di,N]
    bmat = bmat.astype(jnp.float32)                    # [B,S,N]
    cmat = cmat.astype(jnp.float32)                    # [B,S,N]
    uf = u.astype(jnp.float32)

    da = jnp.exp(dt[..., None] * a[None, None])        # [B,S,di,N]
    dbu = dt[..., None] * bmat[:, :, None, :] * uf[..., None]

    def step(h, inputs):
        da_t, dbu_t, c_t = inputs
        h = h * da_t + dbu_t                           # [B,di,N]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    h0 = (state.ssm if state is not None
          else _zero_like_data(x, (b, di, n)))
    xs = (da.transpose(1, 0, 2, 3), dbu.transpose(1, 0, 2, 3),
          cmat.transpose(1, 0, 2))
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2)                          # [B,S,di]
    y = y + uf * params["D"][None, None]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(y.dtype))
    out = logical_constraint(out, ("batch", "seq", "embed"))

    if state is None:
        return out, None
    return out, MambaState(ssm=h_final, conv=new_conv_tail)


def mamba_init_state(x_like, b: int, cfg: ModelConfig) -> MambaState:
    di, _ = mamba_dims(cfg)
    return MambaState(
        ssm=_zero_like_data(x_like, (b, di, cfg.ssm_state_dim)),
        conv=_zero_like_data(x_like, (b, cfg.conv_kernel - 1, di)),
    )


# ==========================================================================
# xLSTM blocks
# ==========================================================================

class MLSTMState(NamedTuple):
    c: jnp.ndarray   # [B, NH, HD, HD] matrix memory
    n: jnp.ndarray   # [B, NH, HD] normalizer
    m: jnp.ndarray   # [B, NH] log-scale stabilizer


class SLSTMState(NamedTuple):
    c: jnp.ndarray   # [B, NH, HD] cell
    n: jnp.ndarray   # [B, NH] normalizer... per-head scalar
    m: jnp.ndarray   # [B, NH] stabilizer


def _init_qkv_gates(key, cfg: ModelConfig, dtype):
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    s = 1.0 / d ** 0.5
    so = 1.0 / (h * hd) ** 0.5
    return {
        "wq": jax.random.normal(ks[0], (d, h, hd), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, h, hd), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, h, hd), dtype) * s,
        "w_if": jax.random.normal(ks[3], (d, h), jnp.float32) * s,
        "w_ff": jax.random.normal(ks[4], (d, h), jnp.float32) * s,
        "w_of": jax.random.normal(ks[5], (d, h), jnp.float32) * s,
        "wo": jax.random.normal(ks[0], (h, hd, d), dtype) * so,
    }


def xlstm_param_specs():
    return {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "heads", "head_dim"),
        "wv": ("embed", "heads", "head_dim"),
        "w_if": ("embed", "heads"),
        "w_ff": ("embed", "heads"),
        "w_of": ("embed", "heads"),
        "wo": ("heads", "head_dim", "embed"),
    }


init_mlstm = _init_qkv_gates
init_slstm = _init_qkv_gates


def mlstm_block(params, x: jnp.ndarray, cfg: ModelConfig,
                state: Optional[MLSTMState] = None):
    """mLSTM: matrix-memory LSTM with exponential gating (xLSTM §2.3)."""
    b, s, d = x.shape
    h, hd = cfg.num_heads, cfg.resolved_head_dim

    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"].astype(x.dtype)) * hd ** -0.5
    k = jnp.einsum("bsd,dnh->bsnh", x, params["wk"].astype(x.dtype)) * hd ** -0.5
    v = jnp.einsum("bsd,dnh->bsnh", x, params["wv"].astype(x.dtype))
    i_pre = jnp.einsum("bsd,dn->bsn", x.astype(jnp.float32), params["w_if"])
    f_pre = jnp.einsum("bsd,dn->bsn", x.astype(jnp.float32), params["w_ff"])
    o_gate = jax.nn.sigmoid(
        jnp.einsum("bsd,dn->bsn", x.astype(jnp.float32), params["w_of"]))

    def step(carry, inputs):
        c, nrm, m = carry
        qt, kt, vt, it, ft = inputs                    # [B,NH,HD]x3, [B,NH]
        m_new = jnp.maximum(ft + m, it)                # log-space stabilizer
        i_act = jnp.exp(it - m_new)
        f_act = jnp.exp(ft + m - m_new)
        c = (f_act[..., None, None] * c
             + i_act[..., None, None]
             * (vt[..., :, None] * kt[..., None, :]).astype(jnp.float32))
        nrm = f_act[..., None] * nrm + i_act[..., None] * kt.astype(
            jnp.float32)
        y = jnp.einsum("bnvk,bnk->bnv", c, qt.astype(jnp.float32))
        denom = jnp.maximum(
            jnp.abs(jnp.einsum("bnk,bnk->bn", nrm, qt.astype(jnp.float32))),
            jnp.exp(-m_new))
        y = y / denom[..., None]
        return (c, nrm, m_new), y

    if state is None:
        c0 = _zero_like_data(x, (b, h, hd, hd))
        n0 = _zero_like_data(x, (b, h, hd))
        m0 = _zero_like_data(x, (b, h))
    else:
        c0, n0, m0 = state

    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), i_pre.transpose(1, 0, 2),
          f_pre.transpose(1, 0, 2))
    (cf, nf, mf), ys = jax.lax.scan(step, (c0, n0, m0), xs)
    y = ys.transpose(1, 0, 2, 3)                       # [B,S,NH,HD]
    y = (y * o_gate[..., None]).astype(x.dtype)
    out = jnp.einsum("bsnh,nhd->bsd", y, params["wo"].astype(y.dtype))
    new_state = MLSTMState(cf, nf, mf) if state is not None else None
    return out, new_state


def mlstm_init_state(x_like, b: int, cfg: ModelConfig) -> MLSTMState:
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    return MLSTMState(
        c=_zero_like_data(x_like, (b, h, hd, hd)),
        n=_zero_like_data(x_like, (b, h, hd)),
        m=_zero_like_data(x_like, (b, h)),
    )


def slstm_block(params, x: jnp.ndarray, cfg: ModelConfig,
                state: Optional[SLSTMState] = None):
    """sLSTM: scalar-memory LSTM with exponential gating (xLSTM §2.2).

    Simplified: recurrence on the cell state only (no hidden-to-gate
    recurrent weights), which keeps the layer scan-parallel across heads.
    """
    b, s, d = x.shape
    h, hd = cfg.num_heads, cfg.resolved_head_dim

    zt = jnp.tanh(
        jnp.einsum("bsd,dnh->bsnh", x, params["wq"].astype(x.dtype)))
    i_pre = jnp.einsum("bsd,dn->bsn", x.astype(jnp.float32), params["w_if"])
    f_pre = jnp.einsum("bsd,dn->bsn", x.astype(jnp.float32), params["w_ff"])
    o_gate = jax.nn.sigmoid(
        jnp.einsum("bsd,dn->bsn", x.astype(jnp.float32), params["w_of"]))

    def step(carry, inputs):
        c, nrm, m = carry
        z_t, it, ft = inputs
        m_new = jnp.maximum(ft + m, it)
        i_act = jnp.exp(it - m_new)
        f_act = jnp.exp(ft + m - m_new)
        c = (f_act[..., None] * c
             + i_act[..., None] * z_t.astype(jnp.float32))
        nrm = f_act * nrm + i_act
        y = c / jnp.maximum(nrm[..., None], 1e-6)
        return (c, nrm, m_new), y

    if state is None:
        c0 = _zero_like_data(x, (b, h, hd))
        n0 = _zero_like_data(x, (b, h))
        m0 = _zero_like_data(x, (b, h))
    else:
        c0, n0, m0 = state

    xs = (zt.transpose(1, 0, 2, 3), i_pre.transpose(1, 0, 2),
          f_pre.transpose(1, 0, 2))
    (cf, nf, mf), ys = jax.lax.scan(step, (c0, n0, m0), xs)
    y = ys.transpose(1, 0, 2, 3)
    y = (y * o_gate[..., None]).astype(x.dtype)
    out = jnp.einsum("bsnh,nhd->bsd", y, params["wo"].astype(y.dtype))
    new_state = SLSTMState(cf, nf, mf) if state is not None else None
    return out, new_state


def slstm_init_state(x_like, b: int, cfg: ModelConfig) -> SLSTMState:
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    return SLSTMState(
        c=_zero_like_data(x_like, (b, h, hd)),
        n=_zero_like_data(x_like, (b, h)),
        m=_zero_like_data(x_like, (b, h)),
    )


# ==========================================================================
# State snapshot seam (paged serving cache)
# ==========================================================================

#: the SSM decode states the paged cache can snapshot/restore.
STATE_TYPES = (MambaState, MLSTMState, SLSTMState)


def state_snapshot(state) -> Tuple[jnp.ndarray, ...]:
    """An SSM decode state's arrays, in field order — what the paged
    serving cache (``repro.serving.kv_cache``) encodes at a block
    boundary. Unlike attention KV there is no growing seq dim: the
    whole carried state IS the block."""
    if not isinstance(state, STATE_TYPES):
        raise TypeError(f"not an SSM decode state: {type(state).__name__}")
    return tuple(state)


def state_restore(state, arrays) -> "MambaState | MLSTMState | SLSTMState":
    """Rebuild a state from :func:`state_snapshot` arrays (the
    decode-on-access epilogue: the recurrence continues from the
    decoded wire form)."""
    if not isinstance(state, STATE_TYPES):
        raise TypeError(f"not an SSM decode state: {type(state).__name__}")
    arrays = tuple(arrays)
    if len(arrays) != len(state):
        raise ValueError(f"{type(state).__name__} expects {len(state)} "
                         f"arrays, got {len(arrays)}")
    return type(state)(*(a.astype(t.dtype).reshape(t.shape)
                         for a, t in zip(arrays, state)))
