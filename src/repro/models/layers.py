"""Shared model layers: norms, rotary embeddings, MLPs, embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import logical_constraint


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6
             ) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)
            ).astype(dt)


def init_rms_norm(d: int, dtype) -> jnp.ndarray:
    return jnp.ones((d,), dtype=dtype)


# ---- rotary ---------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float, fraction: float = 1.0):
    """Inverse frequencies for the rotated sub-dimension."""
    rot = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               fraction: float = 1.0) -> jnp.ndarray:
    """x: [B, S, N, H]; positions: [B, S] int32. Rotates the first
    ``fraction`` of head dims (chatglm3 uses 0.5: 'RoPE 2d' applied to
    half the channels, the rest pass through)."""
    b, s, n, h = x.shape
    inv, rot = rope_freqs(h, theta, fraction)
    if rot == 0:
        return x
    ang = positions[..., None].astype(jnp.float32) * inv  # [B, S, rot/2]
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rotated = jnp.stack([r1, r2], axis=-1).reshape(b, s, n, rot)
    return jnp.concatenate(
        [rotated.astype(x.dtype), x[..., rot:]], axis=-1)


# ---- MLP ------------------------------------------------------------------

def _act(name: str):
    if name == "swiglu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "squared_relu":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def init_mlp(key, d: int, ff: int, activation: str, dtype):
    gated = activation == "swiglu"
    keys = jax.random.split(key, 3)
    scale_in = 1.0 / (d ** 0.5)
    scale_out = 1.0 / (ff ** 0.5)
    p = {
        "w_in": jax.random.normal(keys[0], (d, ff), dtype) * scale_in,
        "w_out": jax.random.normal(keys[1], (ff, d), dtype) * scale_out,
    }
    if gated:
        p["w_gate"] = jax.random.normal(keys[2], (d, ff), dtype) * scale_in
    return p


def mlp(params, x: jnp.ndarray, activation: str) -> jnp.ndarray:
    """x: [B, S, D] -> [B, S, D]."""
    act = _act(activation)
    h = jnp.einsum("bsd,df->bsf", x, params["w_in"].astype(x.dtype))
    if activation == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(x.dtype))
        h = act(g) * h
    else:
        h = act(h)
    h = logical_constraint(h, ("batch", "seq", "mlp"))
    return jnp.einsum("bsf,fd->bsd", h, params["w_out"].astype(x.dtype))


def mlp_param_specs(activation: str):
    specs = {"w_in": ("embed", "mlp"), "w_out": ("mlp", "embed")}
    if activation == "swiglu":
        specs["w_gate"] = ("embed", "mlp")
    return specs


# ---- embeddings -----------------------------------------------------------

def init_embedding(key, vocab: int, d: int, dtype):
    return jax.random.normal(key, (vocab, d), dtype) * (1.0 / d ** 0.5)


def embed(table: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, tokens, axis=0)


def unembed(table_or_head: jnp.ndarray, x: jnp.ndarray, tied: bool
            ) -> jnp.ndarray:
    if tied:
        return jnp.einsum("bsd,vd->bsv", x, table_or_head.astype(x.dtype))
    return jnp.einsum("bsd,dv->bsv", x, table_or_head.astype(x.dtype))
