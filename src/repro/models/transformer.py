"""Model assembly: heterogeneous block stacks (dense / MoE / hybrid /
ssm), scan-over-layer-groups, decode states, loss.

Layer stacks are scanned over *groups* of ``cfg.layer_period`` layers so
heterogeneous interleaves (jamba 1 attention : 7 mamba, xlstm sLSTM/
mLSTM alternation) compile to a single rolled loop — essential to keep
the dry-run HLO small at 96 layers.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers, moe, ssm
from repro.parallel.sharding import logical_constraint


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------

def _init_block(key, kind: str, cfg: ModelConfig, dtype,
                idx_in_group: int = 0):
    kmix, kffn = jax.random.split(key)
    p: Dict[str, Any] = {"norm1": layers.init_rms_norm(cfg.d_model, dtype)}
    if kind == "attention":
        p["mixer"] = attn.init_attention(kmix, cfg, dtype)
    elif kind == "mamba":
        p["mixer"] = ssm.init_mamba(kmix, cfg, dtype)
    elif kind == "mlstm":
        p["mixer"] = ssm.init_mlstm(kmix, cfg, dtype)
    elif kind == "slstm":
        p["mixer"] = ssm.init_slstm(kmix, cfg, dtype)
    else:
        raise ValueError(kind)
    fk = cfg.ffn_kind(idx_in_group)
    if fk != "none":
        p["norm2"] = layers.init_rms_norm(cfg.d_model, dtype)
        if fk == "moe":
            p["ffn"] = moe.init_moe(kffn, cfg, dtype)
        else:
            p["ffn"] = layers.init_mlp(
                kffn, cfg.d_model, cfg.d_ff, cfg.activation, dtype)
    return p


def _block_specs(kind: str, cfg: ModelConfig, idx_in_group: int = 0):
    p: Dict[str, Any] = {"norm1": ("embed",)}
    if kind == "attention":
        p["mixer"] = attn.attention_param_specs()
    elif kind == "mamba":
        p["mixer"] = ssm.mamba_param_specs()
    else:
        p["mixer"] = ssm.xlstm_param_specs()
    fk = cfg.ffn_kind(idx_in_group)
    if fk != "none":
        p["norm2"] = ("embed",)
        if fk == "moe":
            p["ffn"] = moe.moe_param_specs(cfg)
        else:
            p["ffn"] = layers.mlp_param_specs(cfg.activation)
    return p


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    kinds = cfg.layer_kinds()
    n_groups = cfg.num_layers // len(kinds)
    assert n_groups * len(kinds) == cfg.num_layers, (
        f"{cfg.name}: num_layers {cfg.num_layers} not divisible by "
        f"period {len(kinds)}")
    ke, kh, kg = jax.random.split(key, 3)

    def init_group(gkey):
        sub = jax.random.split(gkey, len(kinds))
        return {f"l{i}": _init_block(sub[i], kind, cfg, dtype, i)
                for i, kind in enumerate(kinds)}

    gkeys = jax.random.split(kg, n_groups)
    groups = jax.vmap(init_group)(gkeys)

    params = {
        "embed": layers.init_embedding(ke, cfg.vocab_size, cfg.d_model,
                                       dtype),
        "final_norm": layers.init_rms_norm(cfg.d_model, dtype),
        "groups": groups,
    }
    if not cfg.tie_embeddings:
        params["head"] = (jax.random.normal(
            kh, (cfg.d_model, cfg.vocab_size), dtype)
            * cfg.d_model ** -0.5)
    return params


def param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    kinds = cfg.layer_kinds()
    group = {f"l{i}": _block_specs(kind, cfg, i)
             for i, kind in enumerate(kinds)}
    # prepend the scanned "layers" dim to every leaf spec
    group = jax.tree.map(
        lambda spec: ("layers",) + tuple(spec), group,
        is_leaf=lambda s: isinstance(s, tuple) and all(
            x is None or isinstance(x, str) for x in s))
    specs = {
        "embed": ("vocab", "embed"),
        "final_norm": ("embed",),
        "groups": group,
    }
    if not cfg.tie_embeddings:
        specs["head"] = ("embed", "vocab")
    return specs


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------

def _apply_block(p, kind: str, x, positions, cfg: ModelConfig, state):
    h = layers.rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind == "attention":
        out, new_state = attn.attention_block(p["mixer"], h, cfg, positions,
                                              cache=state)
    elif kind == "mamba":
        out, new_state = ssm.mamba_block(p["mixer"], h, cfg, state=state)
    elif kind == "mlstm":
        out, new_state = ssm.mlstm_block(p["mixer"], h, cfg, state=state)
    elif kind == "slstm":
        out, new_state = ssm.slstm_block(p["mixer"], h, cfg, state=state)
    else:
        raise ValueError(kind)
    x = x + out
    if "ffn" in p:
        h2 = layers.rms_norm(x, p["norm2"], cfg.norm_eps)
        if "router" in p["ffn"]:
            f = moe.moe_block(p["ffn"], h2, cfg)
        else:
            f = layers.mlp(p["ffn"], h2, cfg.activation)
        x = x + f
    x = logical_constraint(x, ("batch", "seq", "embed"))
    return x, new_state


def _apply_group(params_g, x, positions, cfg: ModelConfig, states_g):
    kinds = cfg.layer_kinds()
    new_states = {}
    for i, kind in enumerate(kinds):
        st = states_g[f"l{i}"] if states_g is not None else None
        x, ns = _apply_block(params_g[f"l{i}"], kind, x, positions, cfg, st)
        new_states[f"l{i}"] = ns
    return x, new_states


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)  # "full"


def apply_stack(params, x, positions, cfg: ModelConfig, states=None,
                weight_codec=None):
    """Run all layer groups. states=None (train/prefill-logits) or a
    pytree with leading group dim (decode). ``weight_codec`` (serving):
    group params arrive in compressed wire form and are opened inside
    the scan body, so weight gathers move the wire bytes."""
    groups = params["groups"]

    def open_pg(pg):
        return weight_codec.open_group(pg) if weight_codec is not None \
            else pg

    if states is None:
        def body(carry, pg):
            out, _ = _apply_group(pg, carry, positions, cfg, None)
            return out, None
        body = _maybe_remat(body, cfg)
        if cfg.use_scan:
            x, _ = jax.lax.scan(body, x, groups)
        else:
            n_groups = jax.tree.leaves(groups)[0].shape[0]
            for g in range(n_groups):
                pg = jax.tree.map(lambda a: a[g], groups)
                x, _ = body(x, pg)
        return x, None

    def body_st(carry, inputs):
        pg, sg = inputs
        out, ns = _apply_group(open_pg(pg), carry, positions, cfg, sg)
        return out, ns

    if cfg.use_scan:
        x, new_states = jax.lax.scan(body_st, x, (groups, states))
    else:
        n_groups = jax.tree.leaves(groups)[0].shape[0]
        outs = []
        for g in range(n_groups):
            pg = jax.tree.map(lambda a: a[g], groups)
            sg = jax.tree.map(lambda a: a[g], states)
            x, ns = _apply_group(open_pg(pg), x, positions, cfg, sg)
            outs.append(ns)
        new_states = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    return x, new_states


def _hidden(params, cfg: ModelConfig, tokens: jnp.ndarray,
            prefix_emb: Optional[jnp.ndarray] = None,
            positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    dtype = jnp.dtype(cfg.dtype)
    x = layers.embed(params["embed"], tokens).astype(dtype)
    if prefix_emb is not None:
        x = jnp.concatenate([prefix_emb.astype(dtype), x], axis=1)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = logical_constraint(x, ("batch", "seq", "embed"))
    x, _ = apply_stack(params, x, positions, cfg, states=None)
    return layers.rms_norm(x, params["final_norm"], cfg.norm_eps)


def forward(params, cfg: ModelConfig, tokens: jnp.ndarray,
            prefix_emb: Optional[jnp.ndarray] = None,
            positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """tokens: [B, St] -> logits [B, St(+P), V].

    ``prefix_emb`` [B, P, D] (modality stub) is prepended to the token
    embeddings; total sequence = P + St.
    """
    x = _hidden(params, cfg, tokens, prefix_emb, positions)
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = layers.unembed(head, x, cfg.tie_embeddings)
    return logical_constraint(logits, ("batch", "seq", "vocab"))


def prefill_logits(params, cfg: ModelConfig, tokens: jnp.ndarray,
                   prefix_emb: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Inference prefill: logits for the LAST position only [B, 1, V]
    (the full [B, S, V] tensor is never materialized)."""
    x = _hidden(params, cfg, tokens, prefix_emb)
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    return layers.unembed(head, x[:, -1:], cfg.tie_embeddings)


# --------------------------------------------------------------------------
# Decode
# --------------------------------------------------------------------------

def init_decode_states(cfg: ModelConfig, batch: int, max_len: int):
    """Fresh per-layer decode states, stacked over groups."""
    kinds = cfg.layer_kinds()
    n_groups = cfg.num_layers // len(kinds)
    dtype = jnp.dtype(cfg.dtype)
    dummy = jnp.zeros((1,), jnp.float32)

    def one(kind):
        if kind == "attention":
            return attn.KVCache.init(batch, max_len, cfg.num_kv_heads,
                                     cfg.resolved_head_dim, dtype)
        if kind == "mamba":
            return ssm.mamba_init_state(dummy, batch, cfg)
        if kind == "mlstm":
            return ssm.mlstm_init_state(dummy, batch, cfg)
        if kind == "slstm":
            return ssm.slstm_init_state(dummy, batch, cfg)
        raise ValueError(kind)

    group = {f"l{i}": one(kind) for i, kind in enumerate(kinds)}
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n_groups,) + a.shape), group)


def decode_states_specs(cfg: ModelConfig):
    """Logical-axis specs for decode states (for dry-run shardings)."""
    kinds = cfg.layer_kinds()

    def one(kind):
        if kind == "attention":
            return attn.KVCache(
                k=(None, "batch", "kv_seq", "kv_heads", "head_dim"),
                v=(None, "batch", "kv_seq", "kv_heads", "head_dim"),
                length=(None, "batch"))
        if kind == "mamba":
            return ssm.MambaState(ssm=(None, "batch", "mlp", "state"),
                                  conv=(None, "batch", "conv", "mlp"))
        if kind == "mlstm":
            return ssm.MLSTMState(c=(None, "batch", "heads", None, None),
                                  n=(None, "batch", "heads", "head_dim"),
                                  m=(None, "batch", "heads"))
        if kind == "slstm":
            return ssm.SLSTMState(c=(None, "batch", "heads", "head_dim"),
                                  n=(None, "batch", "heads"),
                                  m=(None, "batch", "heads"))
        raise ValueError(kind)

    return {f"l{i}": one(kind) for i, kind in enumerate(kinds)}


def decode_step(params, cfg: ModelConfig, tokens: jnp.ndarray,
                states, positions: jnp.ndarray, weight_codec=None):
    """One-token decode. tokens: [B, 1]; positions: [B, 1] absolute.

    Returns (logits [B, 1, V], new_states).
    """
    dtype = jnp.dtype(cfg.dtype)
    x = layers.embed(params["embed"], tokens).astype(dtype)
    x = logical_constraint(x, ("batch", "seq", "embed"))
    x, new_states = apply_stack(params, x, positions, cfg, states=states,
                                weight_codec=weight_codec)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = layers.unembed(head, x, cfg.tie_embeddings)
    return logits, new_states


# --------------------------------------------------------------------------
# Loss
# --------------------------------------------------------------------------

def next_token_loss(params, cfg: ModelConfig, tokens: jnp.ndarray,
                    labels: jnp.ndarray,
                    prefix_emb: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean next-token cross entropy. labels: [B, St] aligned to tokens
    (label t = token t+1); prefix positions carry no loss."""
    logits = forward(params, cfg, tokens, prefix_emb)
    if prefix_emb is not None:
        logits = logits[:, prefix_emb.shape[1]:]
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)
