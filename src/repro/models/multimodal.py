"""Modality frontend STUBS (per assignment brief: the transformer
backbone is real; vision/audio encoders provide precomputed embeddings).

``input_specs`` for vlm/audio archs include a ``prefix_emb`` tensor of
precomputed patch/frame embeddings; these helpers synthesize such
embeddings for smoke tests and examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def stub_prefix_embeddings(key, cfg: ModelConfig, batch: int) -> jnp.ndarray:
    """[B, P, D] synthetic patch/frame embeddings (unit-scale Gaussian)."""
    p = cfg.frontend_prefix_len
    return jax.random.normal(key, (batch, p, cfg.d_model),
                             jnp.dtype(cfg.dtype))


def prefix_spec(cfg: ModelConfig, batch: int):
    """ShapeDtypeStruct stand-in for the frontend output."""
    return jax.ShapeDtypeStruct(
        (batch, cfg.frontend_prefix_len, cfg.d_model), jnp.dtype(cfg.dtype))
