"""Composable model zoo covering the 10 assigned architectures."""
from repro.models.transformer import (  # noqa: F401
    prefill_logits,
    decode_states_specs,
    decode_step,
    forward,
    init_decode_states,
    init_params,
    next_token_loss,
    param_specs,
)
from repro.models import attention, layers, moe, multimodal, ssm  # noqa: F401
