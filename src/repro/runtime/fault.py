"""Fault-tolerance runtime: step watchdog, retry policy, elastic restart.

On a real cluster these hooks wrap the multi-host coordinator; here they
wrap the single-process step loop with identical semantics so the logic
is testable (tests kill/restart the training process and resume
bit-exact from the checkpoint).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Optional

log = logging.getLogger("repro.runtime")


@dataclasses.dataclass
class StragglerWatchdog:
    """Flags steps slower than ``threshold`` x the running median.

    On a real deployment the flag triggers hot-spare promotion /
    re-sharding; here it increments a counter and logs (the decision
    layer is pluggable via ``on_straggler``).
    """
    threshold: float = 3.0
    warmup_steps: int = 5
    on_straggler: Optional[Callable[[int, float, float], None]] = None
    _times: list = dataclasses.field(default_factory=list)
    straggler_count: int = 0

    def observe(self, step: int, dt: float):
        if len(self._times) >= self.warmup_steps:
            med = sorted(self._times)[len(self._times) // 2]
            if dt > self.threshold * med:
                self.straggler_count += 1
                log.warning("straggler step %d: %.3fs vs median %.3fs",
                            step, dt, med)
                if self.on_straggler:
                    self.on_straggler(step, dt, med)
        self._times.append(dt)
        if len(self._times) > 100:
            self._times.pop(0)


@dataclasses.dataclass
class RetryPolicy:
    """Retries a step function on transient failures (preemption,
    collective timeout, comm-escape overflow). ``fallback`` (e.g. the
    uncompressed step) handles deterministic comm failures."""
    max_retries: int = 3
    backoff_s: float = 0.1

    def run(self, fn: Callable, *args, fallback: Optional[Callable] = None):
        last = None
        for attempt in range(self.max_retries):
            try:
                return fn(*args)
            except Exception as e:  # pragma: no cover - transient path
                last = e
                log.warning("step failed (attempt %d): %s", attempt + 1, e)
                time.sleep(self.backoff_s * (2 ** attempt))
        if fallback is not None:
            log.warning("falling back after %d failures", self.max_retries)
            return fallback(*args)
        raise last
