from repro.runtime.fault import RetryPolicy, StragglerWatchdog  # noqa: F401
