"""Per-tensor-type codec registry (paper §7: "multiple LUTs, one for
each tensor type ... obtained apriori").

A :class:`CodecRegistry` maps **tensor-type names** ("grads", "ffn1_act",
"params/ffn1", ...) to :class:`CodecEntry` records bundling everything a
codec needs — the :class:`~repro.core.schemes.QLCScheme`, the calibrated
:class:`~repro.core.lut.CodecTables`, the wire
:class:`~repro.comm.planner.CommPlan` — under a **stable small integer
scheme-id**. The scheme-id is what goes on the wire (in the container
header, per-leaf in serving manifests, per-leaf in checkpoint
manifests), so a payload is decodable from the payload bytes plus the
registry alone: no out-of-band ``CommConfig`` agreement.

Construction is calibration-driven (:meth:`CodecRegistry.register` takes
a 256-bin symbol histogram) and deterministic: identical histograms +
scheme produce bit-identical tables on every host (the ranking tie-break
in ``build_tables`` guarantees it), and entries whose derived tables are
bit-identical are deduplicated onto one scheme-id (aliasing names).

The registry itself (de)serializes to JSON — the symbol *ranking*
(tables are a pure function of ranking + scheme) plus scheme shapes and
the calibration histogram — and a reloaded registry rebuilds
bit-identical tables (digest-checked), so containers written by one
process decode bit-exactly in another (checkpoint restore, serving
handoff).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import adapt
from repro.core.lut import CodecTables, build_tables
from repro.core.schemes import NUM_SYMBOLS, QLCScheme

REGISTRY_VERSION = 1

#: scheme-id is carried in a u32 header field / u8 manifest fields.
MAX_SCHEME_ID = 0xFFFF

#: Field names of the autotuned-transport cache key, in key order —
#: the normative spelling documented in docs/transports.md (the docs
#: consistency test asserts the doc matches this tuple).
TRANSPORT_CACHE_KEY = ("scheme_id", "axis", "payload_bucket", "is_reduce")


def payload_bucket(payload_bytes: int) -> int:
    """Power-of-two bucket of a payload size (``ceil(log2(bytes))``).

    The autotune cache (``Channel.autotune``) keys tuned
    ``TransportConfig``s by ``(scheme_id, axis, payload_bucket,
    is_reduce)`` — transport choice is insensitive to sub-2x payload
    variation, so bucketing lets one measurement cover a size class.
    """
    return max(0, int(payload_bytes) - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class CodecEntry:
    """One tensor type's codec: scheme + tables + wire plan, under a
    stable integer id."""

    name: str
    scheme_id: int
    tables: CodecTables
    plan: "CommPlan"                 # repro.comm.planner.CommPlan
    counts: np.ndarray               # [256] calibration histogram

    @property
    def scheme(self) -> QLCScheme:
        return self.tables.scheme

    def config(self, **overrides) -> "CommConfig":
        """The entry's wire format as a ``CommConfig`` (kwargs override,
        e.g. ``use_kernels=True``)."""
        from repro.comm.compressed import CommConfig
        return CommConfig.from_plan(self.plan, **overrides)

    def expected_bits(self) -> float:
        return self.plan.expected_bits_per_symbol


def _tables_digest(tables: CodecTables) -> str:
    """Content digest of everything that affects coded bits."""
    h = hashlib.sha256()
    h.update(np.ascontiguousarray(tables.enc_code).tobytes())
    h.update(np.ascontiguousarray(tables.enc_len).tobytes())
    h.update(np.ascontiguousarray(tables.dec_lut).tobytes())
    h.update(bytes([tables.prefix_bits]))
    return h.hexdigest()


def _tables_from_order(order: np.ndarray, scheme: QLCScheme) -> CodecTables:
    """Rebuild tables from a serialized symbol ranking.

    ``order[rank] = symbol`` (i.e. ``dec_lut``) fully determines the
    tables given the scheme. A synthetic tie-free histogram whose
    descending sort reproduces exactly that ranking feeds
    ``build_tables``, so the result is bit-identical to the original no
    matter what histogram produced it — including entries registered
    from pre-built tables with no histogram at all.
    """
    order = np.asarray(order, dtype=np.int64)
    if sorted(order.tolist()) != list(range(NUM_SYMBOLS)):
        raise ValueError("order must be a permutation of 0..255")
    rank_of = np.empty(NUM_SYMBOLS, dtype=np.float64)
    rank_of[order] = np.arange(NUM_SYMBOLS, dtype=np.float64)
    return build_tables(NUM_SYMBOLS - rank_of, scheme)


class CodecRegistry:
    """Named per-tensor-type codecs with stable scheme-ids.

    Names are aliases: two names whose calibrated tables come out
    bit-identical share one scheme-id (and one wire representation).
    Scheme-ids are assigned densely in registration order unless pinned
    via ``scheme_id=``.
    """

    def __init__(self):
        self._by_name: Dict[str, CodecEntry] = {}
        self._by_id: Dict[int, CodecEntry] = {}
        self._digest_to_id: Dict[str, int] = {}
        # (scheme_id, axis, payload_bucket, is_reduce) -> TransportConfig;
        # written by Channel.autotune, read by the "auto" transport
        # policy, and serialized with the registry so tunings survive
        # reload.
        self._transport_cache: Dict[Tuple[int, str, int, bool],
                                    "TransportConfig"] = {}
        # axis name -> {"link", "wire_Bps", "alpha_s"}; measured wire
        # constants per mesh axis (Channel.measure_wire_Bps), consumed
        # by the per-link-class AlphaBetaModel the planner prices
        # hierarchical transports with.
        self._link_cache: Dict[str, Dict] = {}

    # ---- registration ----------------------------------------------------

    def register(self, name: str, counts: np.ndarray,
                 scheme: Optional[QLCScheme] = None, *,
                 chunk_symbols: int = 1024,
                 target_escape_prob: float = 1e-6,
                 allow_search: bool = False,
                 pool_slots_per_1k: int = 8,
                 scheme_id: Optional[int] = None) -> CodecEntry:
        """Calibrate and register a codec for one tensor type.

        ``counts`` is the 256-bin histogram of the type's e4m3 symbols
        (the paper's apriori calibration). The scheme is auto-selected
        (Table 1 vs Table 2, or searched with ``allow_search``) unless
        given. Re-registering a name with identical resulting tables is
        a no-op returning the existing entry; identical tables under a
        NEW name alias onto the existing scheme-id.
        """
        from repro.comm.planner import plan_for_tables
        counts = np.maximum(
            np.asarray(counts, dtype=np.float64).reshape(NUM_SYMBOLS), 1e-6)
        if scheme is None:
            scheme = adapt.select_scheme(
                counts, allow_search=allow_search).scheme
        tables = build_tables(counts, scheme)
        plan = plan_for_tables(tables, counts, chunk_symbols=chunk_symbols,
                               target_escape_prob=target_escape_prob,
                               pool_slots_per_1k=pool_slots_per_1k)
        return self.register_tables(name, tables, plan, counts=counts,
                                    scheme_id=scheme_id)

    # calibration-driven construction, by its ISSUE name
    register_from_histogram = register

    def register_tables(self, name: str, tables: CodecTables,
                        plan: "CommPlan", *,
                        counts: Optional[np.ndarray] = None,
                        scheme_id: Optional[int] = None,
                        rebind: bool = False) -> CodecEntry:
        """Register pre-built tables + plan under ``name``.

        ``rebind=True`` allows ``name`` to move from an existing entry
        to this one (the previous entry keeps its scheme-id and stays
        decodable by id) — the internal path under
        :meth:`register_revision` and the revision-aware JSON reload;
        without it a name collision with different tables raises.
        """
        if counts is None:
            counts = np.full(NUM_SYMBOLS, 1.0)
        digest = _tables_digest(tables)
        existing_id = self._digest_to_id.get(digest)
        if existing_id is not None and scheme_id in (None, existing_id):
            entry = self._by_id[existing_id]
            if (name in self._by_name
                    and self._by_name[name].scheme_id != existing_id
                    and not rebind):
                raise ValueError(
                    f"name {name!r} already bound to scheme-id "
                    f"{self._by_name[name].scheme_id}")
            self._by_name[name] = entry
            return entry
        if name in self._by_name and not rebind:
            raise ValueError(f"name {name!r} already registered with "
                             "different tables")
        sid = self._next_id() if scheme_id is None else int(scheme_id)
        if not (0 <= sid <= MAX_SCHEME_ID):
            raise ValueError(f"scheme_id {sid} out of range")
        if sid in self._by_id:
            raise ValueError(f"scheme_id {sid} already taken by "
                             f"{self._by_id[sid].name!r}")
        entry = CodecEntry(name=name, scheme_id=sid, tables=tables,
                           plan=plan, counts=np.asarray(counts, np.float64))
        self._by_name[name] = entry
        self._by_id[sid] = entry
        self._digest_to_id[digest] = sid
        return entry

    def register_revision(self, name: str, tables: CodecTables,
                          plan: "CommPlan", *,
                          counts: Optional[np.ndarray] = None
                          ) -> CodecEntry:
        """Register a RECALIBRATED codec for an existing name under a
        fresh scheme-id and atomically rebind the name to it.

        This is the hot-swap primitive (``repro.adaptive``): the
        previous entry is retained, never mutated — it stays reachable
        via :meth:`by_id` (and in :meth:`stacked_decode_tables`), so
        in-flight and checkpointed containers written under the old
        scheme-id decode forever. Only the *name* binding moves; new
        traffic encodes under the new id.

        Identical tables AND plan to the current binding is a no-op
        returning the existing entry (recalibration converged onto the
        deployed codec). A fresh scheme-id is allocated even when the
        tables digest matches some OTHER entry — a revision may change
        only the plan (slot capacity / escape pool), and plans are
        per-entry.
        """
        cur = self._by_name.get(name)
        if cur is None:
            return self.register_tables(name, tables, plan, counts=counts)
        if (_tables_digest(tables) == _tables_digest(cur.tables)
                and plan == cur.plan):
            return cur
        return self.register_tables(name, tables, plan, counts=counts,
                                    scheme_id=self._next_id(), rebind=True)

    def _next_id(self) -> int:
        return max(self._by_id, default=-1) + 1

    # ---- lookup ----------------------------------------------------------

    def __contains__(self, name: str) -> bool:
        return name in self._by_name

    def __len__(self) -> int:
        return len(self._by_id)

    def __getitem__(self, name: str) -> CodecEntry:
        try:
            return self._by_name[name]
        except KeyError:
            raise KeyError(
                f"no codec registered for tensor type {name!r}; "
                f"have {sorted(self._by_name)}") from None

    def get(self, name: str,
            default: Union[str, CodecEntry, None] = None
            ) -> Optional[CodecEntry]:
        """Entry for ``name``, or the fallback when absent.

        ``default`` is either another registry key to resolve (the
        tensor-type fallback, e.g. the weight wire's ``DEFAULT_TYPE``)
        or an already-resolved :class:`CodecEntry` returned as-is —
        so ``get(key, default=entry)`` replaces the
        ``get(key) or entry`` idiom without the falsy-entry pitfall.
        """
        e = self._by_name.get(name)
        if e is None and default is not None:
            if isinstance(default, CodecEntry):
                return default
            e = self._by_name.get(default)
        return e

    def by_id(self, scheme_id: int) -> CodecEntry:
        try:
            return self._by_id[int(scheme_id)]
        except KeyError:
            raise KeyError(
                f"no codec with scheme-id {scheme_id}; "
                f"have {sorted(self._by_id)}") from None

    def names(self) -> List[str]:
        return sorted(self._by_name)

    def entries(self) -> List[CodecEntry]:
        """Distinct entries, ordered by scheme-id."""
        return [self._by_id[i] for i in sorted(self._by_id)]

    def tables_for(self, name: str) -> CodecTables:
        return self[name].tables

    def config_for(self, name: str, **overrides) -> "CommConfig":
        return self[name].config(**overrides)

    # ---- autotuned transport cache (Channel.autotune) --------------------

    def cache_transport(self, scheme_id: int, axis: str,
                        payload_bytes: int, transport: "TransportConfig",
                        *, is_reduce: bool = False):
        """Record an autotuned transport for ``(scheme_id, axis,
        payload bucket, is_reduce)``. Overwrites any previous tuning
        for the key. ``is_reduce`` keys reduce-scatter tunings apart
        from gather/all-to-all ones — the one-shot RS pays per-rank
        accumulate dispatches the other collectives don't, so their
        optimal transports differ at the same payload size.
        """
        from repro.comm.planner import TransportConfig
        if not isinstance(transport, TransportConfig):
            raise TypeError(f"expected TransportConfig, got "
                            f"{type(transport).__name__}")
        key = (int(scheme_id), str(axis), payload_bucket(payload_bytes),
               bool(is_reduce))
        self._transport_cache[key] = transport

    def cached_transport(self, scheme_id: int, axis: str,
                         payload_bytes: int, *, is_reduce: bool = False
                         ) -> Optional["TransportConfig"]:
        """Tuned transport for the payload's size class, or ``None``."""
        return self._transport_cache.get(
            (int(scheme_id), str(axis), payload_bucket(payload_bytes),
             bool(is_reduce)))

    def transport_cache(self) -> Dict[Tuple[int, str, int, bool],
                                      "TransportConfig"]:
        """Read-only view of the tuning cache (tests / diagnostics)."""
        return dict(self._transport_cache)

    # ---- measured per-link-class constants (Channel.autotune) ------------

    def cache_link_constants(self, axis: str, link: str, *,
                             wire_Bps: float,
                             alpha_s: Optional[float] = None):
        """Record measured alpha/beta constants for one mesh axis.

        ``link`` is the axis's link class (``planner.LINK_CLASSES``) —
        the data axis rides ICI, the pod axis DCN. ``wire_Bps`` is the
        measured per-hop wire bandwidth (``Channel.measure_wire_Bps``);
        ``alpha_s`` optionally overrides the class's default latency.
        Serialized with the registry, so one probe run serves every
        later session on the same topology
        (``cached_link_constants``)."""
        from repro.comm.planner import LINK_CLASSES
        if link not in LINK_CLASSES:
            raise ValueError(f"unknown link class {link!r}; "
                             f"valid classes: {LINK_CLASSES}")
        wire_Bps = float(wire_Bps)
        if not wire_Bps > 0:
            raise ValueError(f"wire_Bps must be positive, got {wire_Bps}")
        self._link_cache[str(axis)] = {
            "link": link, "wire_Bps": wire_Bps,
            "alpha_s": None if alpha_s is None else float(alpha_s)}

    def cached_link_constants(self, axis: str) -> Optional[Dict]:
        """Measured constants for ``axis`` (``{"link", "wire_Bps",
        "alpha_s"}``), or ``None`` when that axis was never probed."""
        e = self._link_cache.get(str(axis))
        return None if e is None else dict(e)

    def link_cache(self) -> Dict[str, Dict]:
        """Read-only view of the per-axis link cache."""
        return {a: dict(e) for a, e in self._link_cache.items()}

    # ---- multi-LUT batched decode operands -------------------------------

    def stacked_decode_tables(
            self, scheme_ids: Optional[Sequence[int]] = None
            ) -> Tuple[List[CodecTables], np.ndarray]:
        """Decode-LUT operand set for multi-scheme batched decode.

        Returns ``(tables_list, id_map)`` where ``tables_list[j]`` is the
        tables stacked at slot ``j`` and ``id_map[scheme_id] = j`` maps
        wire scheme-ids to slots (-1 for absent ids). With
        ``scheme_ids`` given, only those schemes are stacked (smaller
        operand for payloads that use a subset).
        """
        ids = sorted(self._by_id) if scheme_ids is None \
            else sorted(set(int(s) for s in scheme_ids))
        tables_list = [self._by_id[i].tables for i in ids]
        id_map = np.full(max(ids, default=0) + 1, -1, dtype=np.int32)
        for j, i in enumerate(ids):
            id_map[i] = j
        return tables_list, id_map

    # ---- (de)serialization ----------------------------------------------

    def to_json_dict(self) -> Dict:
        entries = []
        for entry in self.entries():
            aliases = sorted(n for n, e in self._by_name.items()
                             if e.scheme_id == entry.scheme_id)
            entries.append({
                "name": entry.name,
                "aliases": aliases,
                "scheme_id": entry.scheme_id,
                "areas": [list(a) for a in entry.scheme.areas],
                "prefix_bits": entry.scheme.prefix_bits,
                # the ranking IS the tables (given the scheme); the
                # histogram is informational only
                "order": entry.tables.dec_lut.astype(int).tolist(),
                "digest": _tables_digest(entry.tables),
                "counts": np.asarray(entry.counts, np.float64).tolist(),
                "plan": {
                    "chunk_symbols": entry.plan.chunk_symbols,
                    "capacity_words": entry.plan.capacity_words,
                    "pool_slots_per_1k": entry.plan.pool_slots_per_1k,
                    "expected_bits_per_symbol":
                        entry.plan.expected_bits_per_symbol,
                    "escape_prob_bound": entry.plan.escape_prob_bound,
                    "drift_margin_bits": entry.plan.drift_margin_bits,
                },
            })
        out = {"version": REGISTRY_VERSION, "entries": entries}
        if self._transport_cache:
            out["transport_cache"] = [
                {"scheme_id": sid, "axis": axis, "bucket": bucket,
                 "is_reduce": red, "kind": t.kind,
                 "hop_chunks": t.hop_chunks}
                for (sid, axis, bucket, red), t
                in sorted(self._transport_cache.items())]
        if self._link_cache:
            out["link_cache"] = [
                {"axis": axis, **e}
                for axis, e in sorted(self._link_cache.items())]
        return out

    def to_json(self) -> str:
        return json.dumps(self.to_json_dict())

    @classmethod
    def from_json_dict(cls, d: Dict) -> "CodecRegistry":
        from repro.comm.planner import CommPlan
        if d.get("version") != REGISTRY_VERSION:
            raise ValueError(f"unsupported registry version "
                             f"{d.get('version')!r}")
        reg = cls()
        for e in d["entries"]:
            scheme = QLCScheme(
                areas=tuple(tuple(a) for a in e["areas"]),
                prefix_bits=int(e["prefix_bits"]))
            counts = np.asarray(e["counts"], np.float64)
            tables = _tables_from_order(np.asarray(e["order"]), scheme)
            if e.get("digest") not in (None, _tables_digest(tables)):
                raise ValueError(
                    f"registry entry {e['name']!r}: rebuilt tables do "
                    "not match the recorded digest (corrupt registry?)")
            plan = CommPlan(**{k: v for k, v in e["plan"].items()})
            # Entries are replayed in ascending scheme-id order, so a
            # name that was revised (hot-swapped) lands on its newest
            # revision — rebind permits the name to move off the old
            # entry, which stays decodable by id.
            entry = reg.register_tables(e["name"], tables, plan,
                                        counts=counts,
                                        scheme_id=int(e["scheme_id"]),
                                        rebind=True)
            for alias in e.get("aliases", []):
                reg._by_name[alias] = entry
        if d.get("transport_cache"):
            from repro.comm.planner import TransportConfig
            for c in d["transport_cache"]:
                reg._transport_cache[
                    (int(c["scheme_id"]), str(c["axis"]),
                     int(c["bucket"]),
                     bool(c.get("is_reduce", False)))] = TransportConfig(
                        kind=c["kind"],
                        hop_chunks=int(c.get("hop_chunks", 1)))
        for c in d.get("link_cache", []):
            reg.cache_link_constants(
                c["axis"], c["link"], wire_Bps=c["wire_Bps"],
                alpha_s=c.get("alpha_s"))
        return reg

    @classmethod
    def from_json(cls, s: str) -> "CodecRegistry":
        return cls.from_json_dict(json.loads(s))

    def save(self, path: str):
        with open(path, "w") as f:
            json.dump(self.to_json_dict(), f)

    @classmethod
    def load(cls, path: str) -> "CodecRegistry":
        with open(path) as f:
            return cls.from_json_dict(json.load(f))


def registry_of(obj, name: str = "default") -> CodecRegistry:
    """Wrap bare ``CodecTables`` (legacy call sites) into a one-entry
    registry; pass a ``CodecRegistry`` through unchanged."""
    if isinstance(obj, CodecRegistry):
        return obj
    if isinstance(obj, CodecTables):
        from repro.comm.planner import plan_for_tables
        reg = CodecRegistry()
        counts = np.full(NUM_SYMBOLS, 1.0)
        plan = plan_for_tables(obj, counts)
        reg.register_tables(name, obj, plan, counts=counts)
        return reg
    raise TypeError(f"expected CodecRegistry or CodecTables, got "
                    f"{type(obj).__name__}")
