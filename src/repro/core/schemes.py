"""Quad Length Code schemes (the paper's core contribution, §5-§6).

A scheme divides the 256 ranked symbols into ``2**prefix_bits`` areas.
The area code (the first ``prefix_bits`` bits of every codeword) uniquely
determines the code length, so the decoder never walks a tree: it reads
the prefix, looks up the length, reads the payload, and adds an offset.

Codeword layout (LSB-first software bitstream convention):

    bits [0, prefix_bits)                    : area code
    bits [prefix_bits, prefix_bits+sb)       : symbol index within area

The paper writes codes MSB-first (``000_000``); bit order is an
implementation detail that changes neither lengths nor ratios. We use the
LSB-first convention standard for software entropy coders (cf. DEFLATE).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence, Tuple

import numpy as np

NUM_SYMBOLS = 256


@dataclasses.dataclass(frozen=True)
class QLCScheme:
    """A quad-length-code scheme.

    Attributes:
      areas: tuple of ``(num_symbols, symbol_bits)`` pairs, one per area.
        ``num_symbols <= 2**symbol_bits`` and the totals must sum to 256.
      prefix_bits: number of bits in the area code (3 in the paper).
    """

    areas: Tuple[Tuple[int, int], ...]
    prefix_bits: int = 3

    def __post_init__(self):
        n_areas = len(self.areas)
        if n_areas > (1 << self.prefix_bits):
            raise ValueError(
                f"{n_areas} areas need more than {self.prefix_bits} prefix bits")
        total = 0
        for i, (n, sb) in enumerate(self.areas):
            if n < 1:
                raise ValueError(f"area {i}: num_symbols must be >= 1, got {n}")
            if not (0 <= sb <= 8):
                raise ValueError(f"area {i}: symbol_bits must be in [0, 8], got {sb}")
            if n > (1 << sb):
                raise ValueError(
                    f"area {i}: {n} symbols do not fit in {sb} symbol bits")
            total += n
        if total != NUM_SYMBOLS:
            raise ValueError(f"areas must cover exactly 256 symbols, got {total}")

    # ---- derived tables (all numpy; tiny, computed eagerly) -------------

    @property
    def num_areas(self) -> int:
        return len(self.areas)

    @property
    def area_starts(self) -> np.ndarray:
        """Rank at which each area begins. Shape [num_areas]."""
        sizes = np.array([n for n, _ in self.areas], dtype=np.int32)
        return np.concatenate([[0], np.cumsum(sizes)[:-1]]).astype(np.int32)

    @property
    def area_symbol_bits(self) -> np.ndarray:
        """Symbol bits per area, padded to 2**prefix_bits. Shape [2**prefix]."""
        sb = np.array([s for _, s in self.areas], dtype=np.int32)
        pad = (1 << self.prefix_bits) - len(sb)
        if pad:
            # Unused area codes decode as 0 extra bits; they are never emitted.
            sb = np.concatenate([sb, np.zeros(pad, dtype=np.int32)])
        return sb

    @property
    def area_starts_padded(self) -> np.ndarray:
        starts = self.area_starts
        pad = (1 << self.prefix_bits) - len(starts)
        if pad:
            starts = np.concatenate(
                [starts, np.full(pad, NUM_SYMBOLS - 1, dtype=np.int32)])
        return starts.astype(np.int32)

    @property
    def code_lengths(self) -> np.ndarray:
        """Code length per *rank* (0 = most frequent). Shape [256], int32."""
        out = np.empty(NUM_SYMBOLS, dtype=np.int32)
        r = 0
        for n, sb in self.areas:
            out[r:r + n] = self.prefix_bits + sb
            r += n
        return out

    @property
    def max_code_length(self) -> int:
        return int(self.code_lengths.max())

    @property
    def distinct_lengths(self) -> Tuple[int, ...]:
        return tuple(sorted(set(int(x) for x in self.code_lengths)))

    def rank_codes(self) -> Tuple[np.ndarray, np.ndarray]:
        """(code, length) per rank; LSB-first codeword integers. [256] each."""
        codes = np.empty(NUM_SYMBOLS, dtype=np.uint32)
        lens = self.code_lengths.astype(np.uint32)
        r = 0
        for a, (n, sb) in enumerate(self.areas):
            idx = np.arange(n, dtype=np.uint32)
            codes[r:r + n] = np.uint32(a) | (idx << np.uint32(self.prefix_bits))
            r += n
        return codes, lens

    # ---- metrics ---------------------------------------------------------

    def expected_bits(self, pmf_sorted: np.ndarray) -> float:
        """Average code length given a PMF already sorted descending."""
        pmf_sorted = np.asarray(pmf_sorted, dtype=np.float64)
        if pmf_sorted.shape != (NUM_SYMBOLS,):
            raise ValueError("pmf must have shape (256,)")
        return float(np.dot(pmf_sorted, self.code_lengths))

    def compressibility(self, pmf_sorted: np.ndarray) -> float:
        """Paper's metric: (8 - avg_bits) / 8, for a descending-sorted PMF."""
        return (8.0 - self.expected_bits(pmf_sorted)) / 8.0

    def describe(self) -> str:
        rows = ["area  code  #sym  sym_bits  code_len  range"]
        r = 0
        for a, (n, sb) in enumerate(self.areas):
            code = format(a, f"0{self.prefix_bits}b")
            rows.append(
                f"{a + 1:>4}  {code:>4}  {n:>4}  {sb:>8}  "
                f"{self.prefix_bits + sb:>8}  {r}-{r + n - 1}")
            r += n
        return "\n".join(rows)


# The paper's two published schemes. --------------------------------------

#: Table 1 — FFN1-activation-like distributions (no dominant symbol).
TABLE1 = QLCScheme(
    areas=((8, 3), (8, 3), (8, 3), (8, 3), (8, 3), (16, 4), (32, 5), (168, 8)))

#: Table 2 — FFN2-activation-like distributions (zero spike).
TABLE2 = QLCScheme(
    areas=((2, 1), (8, 3), (8, 3), (8, 3), (8, 3), (32, 5), (32, 5), (158, 8)))

PAPER_SCHEMES = {"table1": TABLE1, "table2": TABLE2}


def scheme_from_area_sizes(sizes: Sequence[int], prefix_bits: int = 3
                           ) -> QLCScheme:
    """Build a scheme from area sizes alone, using the minimal symbol bits."""
    areas = tuple((int(n), max(0, math.ceil(math.log2(n))) if n > 1 else 0)
                  for n in sizes)
    # ceil(log2(1)) == 0; for n>1 use exact bit count.
    fixed = []
    for n, _ in areas:
        sb = 0 if n == 1 else math.ceil(math.log2(n))
        fixed.append((n, sb))
    return QLCScheme(areas=tuple(fixed), prefix_bits=prefix_bits)
