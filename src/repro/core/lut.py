"""Encoder / decoder Look-Up Table construction (paper §7, Tables 3-4).

The encoder LUT maps an *input symbol* (the raw e4m3 byte) to its
codeword + length. The decoder LUT maps the *encoded symbol* (the rank
recovered from area code + payload) back to the output symbol.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import entropy
from repro.core.schemes import NUM_SYMBOLS, QLCScheme


@dataclasses.dataclass(frozen=True)
class CodecTables:
    """Everything the (de)coder needs, as small numpy arrays.

    Attributes:
      enc_code: [256] uint32 — codeword for each *input symbol* (LSB-first).
      enc_len:  [256] uint32 — codeword length in bits for each input symbol.
      dec_lut:  [256] uint8  — rank -> output symbol (paper Table 4).
      area_symbol_bits: [2**prefix] int32 — payload bits per area code.
      area_starts:      [2**prefix] int32 — first rank of each area.
      prefix_bits: int.
      scheme: the generating scheme (for metrics / introspection).
    """

    enc_code: np.ndarray
    enc_len: np.ndarray
    dec_lut: np.ndarray
    area_symbol_bits: np.ndarray
    area_starts: np.ndarray
    prefix_bits: int
    scheme: QLCScheme

    @property
    def max_code_length(self) -> int:
        return int(self.enc_len.max())

    def expected_bits(self, counts: np.ndarray) -> float:
        pmf = entropy.normalize_counts(counts)
        return float(np.dot(self.enc_len.astype(np.float64), pmf))

    def compressibility(self, counts: np.ndarray) -> float:
        return (8.0 - self.expected_bits(counts)) / 8.0


def build_tables(counts: np.ndarray, scheme: QLCScheme) -> CodecTables:
    """Build encoder/decoder LUTs for a symbol-frequency histogram.

    Symbols are ranked by decreasing count (stable, ties broken by symbol
    value — deterministic across hosts, which matters for distributed use:
    every host must derive identical tables from identical counts).
    """
    counts = np.asarray(counts)
    if counts.shape != (NUM_SYMBOLS,):
        raise ValueError("counts must have shape (256,)")
    _, order = entropy.sort_pmf_desc(counts)  # order[rank] = symbol
    rank_of = np.empty(NUM_SYMBOLS, dtype=np.int32)
    rank_of[order] = np.arange(NUM_SYMBOLS, dtype=np.int32)

    rank_code, rank_len = scheme.rank_codes()
    enc_code = rank_code[rank_of].astype(np.uint32)
    enc_len = rank_len[rank_of].astype(np.uint32)
    dec_lut = order.astype(np.uint8)  # rank -> symbol

    return CodecTables(
        enc_code=enc_code,
        enc_len=enc_len,
        dec_lut=dec_lut,
        area_symbol_bits=scheme.area_symbol_bits,
        area_starts=scheme.area_starts_padded,
        prefix_bits=scheme.prefix_bits,
        scheme=scheme,
    )


def identity_tables(scheme: QLCScheme) -> CodecTables:
    """Tables with rank == symbol (uniform counts); useful for tests."""
    return build_tables(np.full(NUM_SYMBOLS, 1.0), scheme)
