"""Beyond-paper: optimal area-layout search (the paper's §8 future work).

The paper's schemes were "obtained empirically". Here we formalize the
problem: pick ``n_areas = 2**prefix_bits`` areas, area ``a`` holding
``n_a <= 2**s_a`` symbols with code length ``prefix_bits + s_a``, covering
all 256 ranks, minimizing the expected code length under a descending
PMF, optionally with at most ``max_distinct_lengths`` distinct lengths
(4 == "quad").

Key structural facts (proved by rearrangement/exchange arguments):
  * With the PMF sorted descending, an optimal scheme uses non-decreasing
    symbol_bits across areas.
  * Given the multiset {s_a}, filling earlier (shorter) areas to capacity
    is optimal — except the total must be exactly 256, so the tail area
    absorbs the remainder.

Hence the search space is exactly the multisets of size ``n_areas`` over
symbol_bits 0..8 — C(16,8)=12870 for 3 prefix bits — which we enumerate
exhaustively and score vectorized. Globally optimal within the code
family, in milliseconds.
"""
from __future__ import annotations

import itertools
from typing import Optional, Tuple

import numpy as np

from repro.core.schemes import NUM_SYMBOLS, QLCScheme


def _fill_areas(sbits: Tuple[int, ...]) -> Optional[Tuple[int, ...]]:
    """Greedy max-fill-early area sizes for a non-decreasing s multiset.

    Returns None if the multiset cannot cover exactly 256 symbols with
    every area holding >= 1 symbol.
    """
    caps = [1 << s for s in sbits]
    n = len(sbits)
    total = sum(caps)
    if total < NUM_SYMBOLS:
        return None
    sizes = []
    remaining = NUM_SYMBOLS
    for i, c in enumerate(caps):
        tail_needed = (n - 1 - i)          # later areas need >= 1 each
        take = min(c, remaining - tail_needed)
        if take < 1:
            return None
        sizes.append(take)
        remaining -= take
    if remaining != 0:
        return None
    return tuple(sizes)


def enumerate_schemes(prefix_bits: int = 3,
                      max_distinct_lengths: Optional[int] = 4):
    """Yield every candidate (sizes, sbits) layout for the search."""
    n_areas = 1 << prefix_bits
    for sbits in itertools.combinations_with_replacement(range(9), n_areas):
        if max_distinct_lengths is not None:
            if len(set(sbits)) > max_distinct_lengths:
                continue
        sizes = _fill_areas(sbits)
        if sizes is None:
            continue
        yield sizes, sbits


def optimal_scheme(pmf_sorted: np.ndarray, prefix_bits: int = 3,
                   max_distinct_lengths: Optional[int] = 4
                   ) -> Tuple[QLCScheme, float]:
    """Exhaustively find the minimum-expected-bits scheme.

    Args:
      pmf_sorted: [256] descending-sorted PMF.
      prefix_bits: area-code width (3 => 8 areas, as in the paper).
      max_distinct_lengths: cap on distinct code lengths (4 == quad;
        None => unconstrained within the family).

    Returns:
      (scheme, expected_bits).
    """
    pmf_sorted = np.asarray(pmf_sorted, dtype=np.float64)
    if pmf_sorted.shape != (NUM_SYMBOLS,):
        raise ValueError("pmf must have shape (256,)")
    csum = np.concatenate([[0.0], np.cumsum(pmf_sorted)])

    best_cost = np.inf
    best: Optional[QLCScheme] = None
    for sizes, sbits in enumerate_schemes(prefix_bits, max_distinct_lengths):
        # cost = sum over areas of (prefix+s) * P(area's rank span)
        cost = 0.0
        r = 0
        for n, s in zip(sizes, sbits):
            cost += (prefix_bits + s) * (csum[r + n] - csum[r])
            r += n
        if cost < best_cost - 1e-15:
            best_cost = cost
            best = QLCScheme(areas=tuple(zip(sizes, sbits)),
                             prefix_bits=prefix_bits)
    assert best is not None
    return best, float(best_cost)


def search_report(pmf_sorted: np.ndarray) -> dict:
    """Compare paper tables vs searched optima. Returns a metrics dict."""
    from repro.core.schemes import TABLE1, TABLE2  # local to avoid cycle
    out = {}
    out["table1_bits"] = TABLE1.expected_bits(pmf_sorted)
    out["table2_bits"] = TABLE2.expected_bits(pmf_sorted)
    quad, quad_bits = optimal_scheme(pmf_sorted, 3, 4)
    free, free_bits = optimal_scheme(pmf_sorted, 3, None)
    out["opt_quad_bits"] = quad_bits
    out["opt_quad_scheme"] = quad
    out["opt_free_bits"] = free_bits
    out["opt_free_scheme"] = free
    for k in ("table1", "table2", "opt_quad", "opt_free"):
        out[k + "_compressibility"] = (8.0 - out[k + "_bits"]) / 8.0
    return out
