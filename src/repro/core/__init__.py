"""Quad Length Codes — core library (the paper's contribution)."""
from repro.core.schemes import (  # noqa: F401
    NUM_SYMBOLS,
    PAPER_SCHEMES,
    QLCScheme,
    TABLE1,
    TABLE2,
)
from repro.core.lut import CodecTables, build_tables, identity_tables  # noqa: F401
from repro.core.registry import (  # noqa: F401
    CodecEntry,
    CodecRegistry,
    registry_of,
)
from repro.core.adapt import (  # noqa: F401
    AdaptResult,
    calibrate_tables,
    default_scheme_for,
    select_scheme,
)
from repro.core import codec, distributions, entropy, huffman, scheme_search  # noqa: F401
