"""Synthetic e4m3 symbol streams reproducing the paper's settings (§3-§4).

The paper's traces (Gemma-2B SFT FFN1/FFN2 tensors) are not public. We
reproduce their qualitative structure exactly as described:

  * FFN1 activations: pre-nonlinearity, roughly zero-mean Gaussian ->
    no dominant symbol; sorted PMF decays smoothly (paper Fig 1,
    entropy ~6.69 bits).
  * FFN2 activations: post-GELU -> a large zero spike plus a positive
    half-Gaussian tail (paper Fig 4, entropy ~6.11 bits).

Streams are produced by actually quantizing synthetic activations to
block-32 e4m3 (the paper's §3 pipeline), not by sampling a target PMF,
so all downstream structure (sign symmetry, exponent banding, Fig 7's
"most frequent symbols are 113, 241, ..." pattern) emerges naturally.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.quant import e4m3

NUM_SYMBOLS = 256


def histogram256(symbols: np.ndarray) -> np.ndarray:
    """Counts[256] of a uint8 symbol array (numpy)."""
    return np.bincount(
        np.asarray(symbols, dtype=np.uint8).reshape(-1), minlength=256
    ).astype(np.float64)


def _gaussian(key, n: int, std: float = 1.0) -> jnp.ndarray:
    return std * jax.random.normal(key, (n,), dtype=jnp.float32)


def ffn1_symbols(n: int = 1 << 20, seed: int = 0,
                 outlier_frac: float = 0.01) -> np.ndarray:
    """FFN1-activation-like stream: Gaussian with a mild heavy tail,
    block-32 e4m3 quantized."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    n = (n // e4m3.BLOCK) * e4m3.BLOCK
    x = _gaussian(k1, n)
    # Mild heavy tail: a few blocks carry larger activations (real
    # activations are not iid; this widens the exponent usage as in Fig 1).
    boost = jnp.where(jax.random.uniform(k2, (n,)) < outlier_frac,
                      4.0 + 4.0 * jax.random.uniform(k3, (n,)), 1.0)
    codes, _ = e4m3.quantize_block32(x * boost)
    return np.asarray(codes, dtype=np.uint8)


def ffn2_symbols(n: int = 1 << 20, seed: int = 1,
                 zero_frac: float = 0.18) -> np.ndarray:
    """FFN2-activation-like stream: post-nonlinearity (zero spike +
    positive-heavy tail), block-32 e4m3 quantized.

    The paper's Fig 4 shows one symbol (zero) dominating "due to the
    intervening non-linear activation function"; ``zero_frac`` models the
    exactly-zero mass (ReLU-family zeros / padding), the rest is GELU
    output.
    """
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    n = (n // e4m3.BLOCK) * e4m3.BLOCK
    x = _gaussian(k1, n)
    y = jax.nn.gelu(x)
    y = jnp.where(jax.random.uniform(k2, (n,)) < zero_frac, 0.0, y)
    codes, _ = e4m3.quantize_block32(y)
    return np.asarray(codes, dtype=np.uint8)


def grad_symbols(n: int = 1 << 20, seed: int = 2) -> np.ndarray:
    """Weight-gradient-like stream (zero-mean, heavier tails: logistic)."""
    key = jax.random.PRNGKey(seed)
    n = (n // e4m3.BLOCK) * e4m3.BLOCK
    x = jax.random.logistic(key, (n,), dtype=jnp.float32)
    codes, _ = e4m3.quantize_block32(x)
    return np.asarray(codes, dtype=np.uint8)


def ffn1_counts(n: int = 1 << 20, seed: int = 0) -> np.ndarray:
    return histogram256(ffn1_symbols(n, seed))


def ffn2_counts(n: int = 1 << 20, seed: int = 1) -> np.ndarray:
    return histogram256(ffn2_symbols(n, seed))


def grad_counts(n: int = 1 << 20, seed: int = 2) -> np.ndarray:
    return histogram256(grad_symbols(n, seed))
