"""Scheme adaptation (paper §6) + calibration plumbing.

The paper shows that one fixed scheme (Table 1) loses badly on a
distribution with a dominant symbol (FFN2 activations post-nonlinearity):
16.7% vs the adapted Table 2's 19.0%. Deployment keeps one LUT per
tensor type, calibrated apriori (paper §7). This module picks or builds
the scheme for a measured histogram.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import entropy, lut, scheme_search
from repro.core.schemes import PAPER_SCHEMES, QLCScheme, TABLE1, TABLE2


@dataclasses.dataclass(frozen=True)
class AdaptResult:
    scheme: QLCScheme
    scheme_name: str
    expected_bits: float
    compressibility: float
    entropy_bits: float
    ideal_compressibility: float


def select_scheme(counts: np.ndarray, allow_search: bool = False,
                  prefix_bits: int = 3) -> AdaptResult:
    """Pick the best scheme for a histogram.

    With ``allow_search=False`` chooses between the paper's Table 1 and
    Table 2 (what the paper does manually). With ``allow_search=True``
    additionally runs the beyond-paper exhaustive quad-constrained search.
    """
    pmf_sorted, _ = entropy.sort_pmf_desc(counts)
    h = entropy.shannon_entropy(pmf_sorted)

    candidates = {name: s for name, s in PAPER_SCHEMES.items()}
    if allow_search:
        opt, _ = scheme_search.optimal_scheme(pmf_sorted, prefix_bits, 4)
        candidates["searched"] = opt

    best_name, best_scheme, best_bits = None, None, np.inf
    for name, scheme in candidates.items():
        bits = scheme.expected_bits(pmf_sorted)
        if bits < best_bits:
            best_name, best_scheme, best_bits = name, scheme, bits

    return AdaptResult(
        scheme=best_scheme,
        scheme_name=best_name,
        expected_bits=float(best_bits),
        compressibility=(8.0 - best_bits) / 8.0,
        entropy_bits=float(h),
        ideal_compressibility=(8.0 - h) / 8.0,
    )


def calibrate_tables(counts: np.ndarray, scheme: Optional[QLCScheme] = None,
                     allow_search: bool = False) -> lut.CodecTables:
    """Histogram -> ready-to-use codec tables (one per tensor type)."""
    if scheme is None:
        scheme = select_scheme(counts, allow_search=allow_search).scheme
    return lut.build_tables(counts, scheme)


def has_dominant_symbol(counts: np.ndarray, threshold: float = 0.15) -> bool:
    """Heuristic from §6: a zero-spike distribution wants Table 2."""
    pmf = entropy.normalize_counts(counts)
    return bool(pmf.max() >= threshold)


def default_scheme_for(counts: np.ndarray) -> QLCScheme:
    """Cheap static rule mirroring the paper's manual choice."""
    return TABLE2 if has_dominant_symbol(counts) else TABLE1
