"""Canonical Huffman baseline (the paper compares QLC against it).

Provides: code-length construction (heap-based, deterministic),
canonical codes, an encoder, and the deliberately bit-sequential
tree-walking decoder that represents the complexity QLC removes.
"""
from __future__ import annotations

import heapq
from typing import List, Tuple

import numpy as np

NUM_SYMBOLS = 256


def code_lengths(counts: np.ndarray) -> np.ndarray:
    """Huffman code lengths per symbol. Zero-count symbols get length 0
    (they are never emitted; callers wanting a total code should smooth).

    Deterministic: ties broken by (count, min symbol in subtree).
    """
    counts = np.asarray(counts, dtype=np.float64)
    if counts.shape != (NUM_SYMBOLS,):
        raise ValueError("counts must have shape (256,)")
    active = [int(s) for s in range(NUM_SYMBOLS) if counts[s] > 0]
    lengths = np.zeros(NUM_SYMBOLS, dtype=np.int32)
    if len(active) == 0:
        raise ValueError("at least one symbol must have nonzero count")
    if len(active) == 1:
        lengths[active[0]] = 1
        return lengths

    # Heap of (count, tiebreak, node). Leaves are ints, internal nodes lists.
    heap: List[Tuple[float, int, object]] = [
        (float(counts[s]), s, s) for s in active]
    heapq.heapify(heap)
    uid = NUM_SYMBOLS
    while len(heap) > 1:
        c1, _, n1 = heapq.heappop(heap)
        c2, _, n2 = heapq.heappop(heap)
        heapq.heappush(heap, (c1 + c2, uid, (n1, n2)))
        uid += 1

    def walk(node, depth):
        if isinstance(node, int):
            lengths[node] = max(depth, 1)
        else:
            walk(node[0], depth + 1)
            walk(node[1], depth + 1)

    walk(heap[0][2], 0)
    return lengths


def canonical_codes(lengths: np.ndarray) -> np.ndarray:
    """Canonical Huffman codes (MSB-first integers) from lengths.

    Symbols with length 0 get code 0 (unused).
    """
    lengths = np.asarray(lengths, dtype=np.int32)
    codes = np.zeros(NUM_SYMBOLS, dtype=np.uint64)
    order = sorted((int(l), s) for s, l in enumerate(lengths) if l > 0)
    code = 0
    prev_len = order[0][0] if order else 0
    for l, s in order:
        code <<= (l - prev_len)
        codes[s] = code
        code += 1
        prev_len = l
    return codes


class HuffmanCodec:
    """Reference Huffman codec over 256 symbols."""

    def __init__(self, counts: np.ndarray):
        counts = np.asarray(counts, dtype=np.float64)
        self.lengths = code_lengths(counts)
        self.codes = canonical_codes(self.lengths)
        self._build_tree()

    def _build_tree(self):
        # Binary tree as flat arrays: children[node, bit] -> node or -(sym+1).
        nodes = [[-0, -0]]  # root; 0 means "unassigned child"
        children = nodes

        def insert(sym, code, length):
            node = 0
            for i in range(length - 1, -1, -1):
                bit = (code >> i) & 1
                nxt = children[node][bit]
                if i == 0:
                    children[node][bit] = -(sym + 1)
                else:
                    if nxt <= 0:
                        children.append([0, 0])
                        nxt = len(children) - 1
                        children[node][bit] = nxt
                    node = nxt

        for s in range(NUM_SYMBOLS):
            l = int(self.lengths[s])
            if l > 0:
                insert(s, int(self.codes[s]), l)
        self.children = np.array(children, dtype=np.int64)

    # -- metrics ----------------------------------------------------------

    def expected_bits(self, counts: np.ndarray) -> float:
        counts = np.asarray(counts, dtype=np.float64)
        pmf = counts / counts.sum()
        return float(np.dot(self.lengths.astype(np.float64), pmf))

    def compressibility(self, counts: np.ndarray) -> float:
        return (8.0 - self.expected_bits(counts)) / 8.0

    # -- encode / decode (numpy bitstream, MSB-first) ----------------------

    def encode(self, symbols: np.ndarray) -> Tuple[np.ndarray, int]:
        """Encode to a packed uint8 MSB-first bitstream. Returns (bytes, nbits)."""
        symbols = np.asarray(symbols, dtype=np.int64).reshape(-1)
        lens = self.lengths[symbols].astype(np.int64)
        nbits = int(lens.sum())
        offsets = np.concatenate([[0], np.cumsum(lens)[:-1]])
        out = np.zeros((nbits + 7) // 8, dtype=np.uint8)
        codes = self.codes[symbols]
        # Bit-by-bit emit (reference implementation; clarity over speed).
        for i in range(symbols.shape[0]):
            c, l, o = int(codes[i]), int(lens[i]), int(offsets[i])
            for b in range(l):
                bit = (c >> (l - 1 - b)) & 1
                if bit:
                    out[(o + b) >> 3] |= 0x80 >> ((o + b) & 7)
        return out, nbits

    def decode(self, data: np.ndarray, nbits: int, n_symbols: int
               ) -> np.ndarray:
        """Bit-sequential tree-walking decode — the baseline the paper's
        speed claim is about. Each output symbol requires `length` branch
        decisions; decode latency is proportional to total encoded bits."""
        out = np.empty(n_symbols, dtype=np.uint8)
        pos = 0
        children = self.children
        for i in range(n_symbols):
            node = 0
            while True:
                bit = (data[pos >> 3] >> (7 - (pos & 7))) & 1
                pos += 1
                nxt = children[node][bit]
                if nxt <= 0:
                    out[i] = -nxt - 1
                    break
                node = nxt
        return out
