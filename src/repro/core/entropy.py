"""Entropy / compressibility metrics (paper §4)."""
from __future__ import annotations

import numpy as np

NUM_SYMBOLS = 256


def normalize_counts(counts: np.ndarray) -> np.ndarray:
    counts = np.asarray(counts, dtype=np.float64)
    total = counts.sum()
    if total <= 0:
        raise ValueError("counts must sum to a positive value")
    return counts / total


def shannon_entropy(pmf: np.ndarray) -> float:
    """Shannon entropy in bits. Zero-probability symbols contribute 0."""
    pmf = np.asarray(pmf, dtype=np.float64)
    nz = pmf[pmf > 0]
    return float(-(nz * np.log2(nz)).sum())


def ideal_compressibility(pmf: np.ndarray, symbol_bits: int = 8) -> float:
    """Paper's ideal bound: (b - H) / b."""
    return (symbol_bits - shannon_entropy(pmf)) / symbol_bits


def avg_code_length(lengths: np.ndarray, pmf: np.ndarray) -> float:
    """Expected code length of a code with per-symbol ``lengths`` under pmf.

    ``lengths`` and ``pmf`` must be aligned (same symbol order).
    """
    lengths = np.asarray(lengths, dtype=np.float64)
    pmf = np.asarray(pmf, dtype=np.float64)
    return float(np.dot(lengths, pmf))


def compressibility(lengths: np.ndarray, pmf: np.ndarray,
                    symbol_bits: int = 8) -> float:
    """Paper's achieved metric: (b - avg_bits) / b."""
    return (symbol_bits - avg_code_length(lengths, pmf)) / symbol_bits


def sort_pmf_desc(counts: np.ndarray):
    """Sort counts descending (stable; ties broken by symbol value).

    Returns (pmf_sorted, order) where ``order[rank] = symbol``.
    """
    counts = np.asarray(counts)
    if counts.shape != (NUM_SYMBOLS,):
        raise ValueError("counts must have shape (256,)")
    if counts.astype(np.float64).sum() <= 0:
        # Degenerate (e.g. uncalibrated) histogram: uniform / identity rank.
        counts = np.ones(NUM_SYMBOLS, dtype=np.float64)
    # argsort ascending on (-count, symbol) => stable deterministic ranking.
    order = np.lexsort((np.arange(NUM_SYMBOLS), -counts.astype(np.float64)))
    pmf = normalize_counts(counts)[order]
    return pmf, order.astype(np.int32)
