"""Pure-JAX chunked QLC codec.

This is the framework's reference codec: it lowers into jit graphs (used
directly inside compressed collectives on the dry-run path) and doubles
as the oracle for the Pallas kernels in ``repro.kernels``.

Layout: the symbol stream is split into fixed-size chunks of ``K``
symbols. Each chunk is encoded independently into a fixed slot of
``capacity_words`` 32-bit words (LSB-first bit order). Chunks are
mutually independent => both encode and decode vectorize across chunks,
which is exactly the TPU-native adaptation of the paper's hardware
decoder: per-symbol decode is O(1) (area code -> length -> offset), and
parallelism comes from many chunks in flight, not from bit-level tricks.
"""
from __future__ import annotations

import math
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lut import CodecTables

MAX_CODE_BITS = 11  # paper schemes top out at 3 + 8


def stack_decode_tables(tables_list: Sequence[CodecTables]):
    """Stack decoder LUTs of several schemes for multi-LUT batched decode.

    All schemes must share ``prefix_bits`` (3 for every paper scheme).
    Returns ``(dec_lut [S, 256], area_symbol_bits [S, 2**p],
    area_starts [S, 2**p], prefix_bits)`` as numpy arrays.
    """
    if not tables_list:
        raise ValueError("need at least one CodecTables")
    pb = tables_list[0].prefix_bits
    for t in tables_list:
        if t.prefix_bits != pb:
            raise ValueError(
                "multi-LUT decode needs a uniform prefix_bits, got "
                f"{sorted({t.prefix_bits for t in tables_list})}")
    dec = np.stack([t.dec_lut for t in tables_list])
    sb = np.stack([t.area_symbol_bits for t in tables_list])
    st = np.stack([t.area_starts for t in tables_list])
    return dec, sb, st, pb


def worst_case_words(chunk_symbols: int, max_code_bits: int = MAX_CODE_BITS
                     ) -> int:
    """Slot size that can hold any chunk (guaranteed-lossless capacity)."""
    return math.ceil(chunk_symbols * max_code_bits / 32) + 1


def raw_words(chunk_symbols: int) -> int:
    """Words needed to store a chunk raw (8 bits/symbol)."""
    return math.ceil(chunk_symbols * 8 / 32)


def _tables_to_jnp(tables: CodecTables):
    return (
        jnp.asarray(tables.enc_code, dtype=jnp.uint32),
        jnp.asarray(tables.enc_len, dtype=jnp.uint32),
        jnp.asarray(tables.dec_lut, dtype=jnp.uint8),
        jnp.asarray(tables.area_symbol_bits, dtype=jnp.uint32),
        jnp.asarray(tables.area_starts, dtype=jnp.uint32),
    )


# --------------------------------------------------------------------------
# Encode
# --------------------------------------------------------------------------

def encode_chunk_bits(symbols: jnp.ndarray, enc_len: jnp.ndarray
                      ) -> jnp.ndarray:
    """Total encoded bits per chunk. symbols: [..., K] uint8 -> [...] uint32."""
    lens = jnp.take(enc_len, symbols.astype(jnp.int32), axis=0)
    return jnp.sum(lens, axis=-1, dtype=jnp.uint32)


def encode_chunks(symbols: jnp.ndarray, tables: CodecTables,
                  capacity_words: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Encode chunks of symbols into fixed word slots.

    Args:
      symbols: uint8 [..., n_chunks, K].
      tables: codec tables.
      capacity_words: slot size per chunk, in 32-bit words.

    Returns:
      words: uint32 [..., n_chunks, capacity_words]. Bits beyond the
        encoded length are zero. If a chunk does not fit, its slot
        contents are unspecified — callers must consult ``nbits``.
      nbits: uint32 [..., n_chunks] — exact encoded bit count
        (valid even when it exceeds the slot).
    """
    enc_code, enc_len, _, _, _ = _tables_to_jnp(tables)

    sym = symbols.astype(jnp.int32)
    codes = jnp.take(enc_code, sym, axis=0)          # [..., n_chunks, K] u32
    lens = jnp.take(enc_len, sym, axis=0)            # [..., n_chunks, K] u32

    nbits = jnp.sum(lens, axis=-1, dtype=jnp.uint32)
    offsets = jnp.cumsum(lens, axis=-1, dtype=jnp.uint32) - lens  # exclusive

    word_idx = (offsets >> 5).astype(jnp.int32)       # [..., K]
    shift = offsets & jnp.uint32(31)

    # A code of <= 11 bits at bit offset `shift` spans at most 2 words.
    lo = codes << shift                               # u32 shift wraps mod 2^32
    hi = jnp.where(shift == 0, jnp.uint32(0),
                   codes >> (jnp.uint32(32) - shift))

    out_shape = symbols.shape[:-1] + (capacity_words,)
    words = jnp.zeros(out_shape, dtype=jnp.uint32)
    # Disjoint bit ranges => add == or. Clip indices of out-of-slot writes.
    word_idx = jnp.minimum(word_idx, capacity_words - 1)
    hi_idx = jnp.minimum(word_idx + 1, capacity_words - 1)
    words = _scatter_add_last(words, word_idx, lo)
    words = _scatter_add_last(words, hi_idx, hi)
    return words, nbits


def _scatter_add_last(words: jnp.ndarray, idx: jnp.ndarray,
                      vals: jnp.ndarray) -> jnp.ndarray:
    """words[..., W] += segment-sum of vals[..., K] at idx[..., K].

    Implemented as a batched one-hot-free scatter-add over the last axis.
    """
    w = words.shape[-1]
    flat_words = words.reshape(-1, w)
    flat_idx = idx.reshape(-1, idx.shape[-1])
    flat_vals = vals.reshape(-1, vals.shape[-1])

    def one(wds, ix, vl):
        return wds.at[ix].add(vl, mode="drop")

    out = jax.vmap(one)(flat_words, flat_idx, flat_vals)
    return out.reshape(words.shape)


# --------------------------------------------------------------------------
# Decode
# --------------------------------------------------------------------------

def decode_chunks(words: jnp.ndarray, tables: CodecTables,
                  chunk_symbols: int) -> jnp.ndarray:
    """Decode fixed-slot chunks back to symbols.

    Args:
      words: uint32 [..., n_chunks, capacity_words].
      tables: codec tables.
      chunk_symbols: K, symbols per chunk.

    Returns:
      symbols: uint8 [..., n_chunks, K].

    Single-scheme specialization of :func:`decode_chunks_multi` (S=1,
    every gather offset folds to zero) — one copy of the bit-window
    loop serves both paths.
    """
    return decode_chunks_multi(words, [tables], jnp.int32(0),
                               chunk_symbols)


def decode_chunks_multi(words: jnp.ndarray,
                        tables_list: Sequence[CodecTables],
                        scheme_ids: jnp.ndarray,
                        chunk_symbols: int) -> jnp.ndarray:
    """Decode chunks encoded under DIFFERENT schemes in one vectorized
    pass (multi-LUT batched decode, paper §7 deployment).

    Args:
      words: uint32 [..., n_chunks, capacity_words].
      tables_list: the stacked schemes; ``scheme_ids`` index into it.
      scheme_ids: int [n_chunks] or [..., n_chunks] — per-chunk slot
        into ``tables_list``.
      chunk_symbols: K, symbols per chunk (uniform across schemes).

    Returns:
      symbols: uint8 [..., n_chunks, K].

    Mirrors :func:`decode_chunks` exactly — the per-symbol O(1) step
    just gathers from LUTs flattened as ``[S * table_len]`` at offset
    ``sid * table_len``, so chunks of every scheme decode in lockstep.
    """
    dec_np, sb_np, st_np, prefix = stack_decode_tables(tables_list)
    s, a = sb_np.shape
    dec_flat = jnp.asarray(dec_np, jnp.uint32).reshape(-1)   # [S*256]
    sb_flat = jnp.asarray(sb_np, jnp.uint32).reshape(-1)     # [S*A]
    st_flat = jnp.asarray(st_np, jnp.uint32).reshape(-1)
    prefix_bits = jnp.uint32(prefix)
    prefix_mask = jnp.uint32((1 << prefix) - 1)

    lead = words.shape[:-1]
    w = words.shape[-1]
    flat = words.reshape(-1, w)
    n = flat.shape[0]
    sid = jnp.broadcast_to(
        jnp.asarray(scheme_ids, jnp.int32), lead).reshape(-1)

    def body(i, state):
        bitpos, out = state
        widx = (bitpos >> 5).astype(jnp.int32)
        shift = bitpos & jnp.uint32(31)
        w0 = jnp.take_along_axis(flat, widx[:, None], axis=1)[:, 0]
        w1 = jnp.take_along_axis(
            flat, jnp.minimum(widx + 1, w - 1)[:, None], axis=1)[:, 0]
        window = (w0 >> shift) | jnp.where(
            shift == 0, jnp.uint32(0), w1 << (jnp.uint32(32) - shift))
        area = (window & prefix_mask).astype(jnp.int32)
        sb = jnp.take(sb_flat, sid * a + area)
        payload = (window >> prefix_bits) & ((jnp.uint32(1) << sb) - 1)
        rank = jnp.take(st_flat, sid * a + area) + payload
        sym = jnp.take(dec_flat,
                       sid * 256 + jnp.minimum(rank, 255).astype(jnp.int32))
        out = out.at[:, i].set(sym.astype(jnp.uint8))
        return bitpos + prefix_bits + sb, out

    bitpos0 = flat[:, 0] & jnp.uint32(0)
    out0 = (jnp.zeros((n, chunk_symbols), dtype=jnp.uint8)
            | (flat[:, :1] & jnp.uint32(0)).astype(jnp.uint8))
    _, out = jax.lax.fori_loop(0, chunk_symbols, body, (bitpos0, out0))
    return out.reshape(lead + (chunk_symbols,))


# --------------------------------------------------------------------------
# Whole-array convenience wrappers (guaranteed capacity)
# --------------------------------------------------------------------------

def pad_to_chunks(symbols: jnp.ndarray, chunk_symbols: int
                  ) -> Tuple[jnp.ndarray, int]:
    """Flatten + zero-pad a symbol array to [n_chunks, K]."""
    flat = symbols.reshape(-1)
    n = flat.shape[0]
    n_chunks = -(-n // chunk_symbols)
    pad = n_chunks * chunk_symbols - n
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(n_chunks, chunk_symbols), n


def encode_stream(symbols: jnp.ndarray, tables: CodecTables,
                  chunk_symbols: int = 1024):
    """Encode any uint8 array with worst-case (always-fits) slots."""
    cap = worst_case_words(chunk_symbols, tables.max_code_length)
    chunks, n = pad_to_chunks(symbols, chunk_symbols)
    words, nbits = encode_chunks(chunks, tables, cap)
    return words, nbits, n


def decode_stream(words: jnp.ndarray, tables: CodecTables,
                  chunk_symbols: int, n: int, shape=None) -> jnp.ndarray:
    out = decode_chunks(words, tables, chunk_symbols).reshape(-1)[:n]
    if shape is not None:
        out = out.reshape(shape)
    return out


def compressed_bits(symbols: jnp.ndarray, tables: CodecTables) -> jnp.ndarray:
    """Exact compressed size in bits (no packing needed). float32 to avoid
    uint32 overflow on multi-GB streams."""
    enc_len = jnp.asarray(tables.enc_len, dtype=jnp.float32)
    lens = jnp.take(enc_len, symbols.astype(jnp.int32).reshape(-1), axis=0)
    return jnp.sum(lens, dtype=jnp.float32)


def measured_compressibility(symbols: np.ndarray, tables: CodecTables
                             ) -> float:
    """(8 - avg_bits)/8 measured on actual data (numpy, exact)."""
    syms = np.asarray(symbols).reshape(-1)
    lens = tables.enc_len[syms.astype(np.int64)]
    avg = lens.mean(dtype=np.float64)
    return float((8.0 - avg) / 8.0)
